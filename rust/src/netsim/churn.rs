//! Scripted node churn: seeded Poisson join/leave/crash schedules.
//!
//! A [`ChurnPlan`] is generated *entirely up front* from a seed and a
//! [`ChurnConfig`]: per-node alternating up/down sessions with
//! exponentially distributed lengths (median up-session =
//! `session_half_life`), each departure being a clean leave or a crash
//! (no goodbye). Because the whole trace is a pure function of the seed,
//! the determinism contract is simple: **same seed ⇒ same event trace**,
//! byte for byte — verified by `tests/dht_churn.rs`.
//!
//! The plan is applied from the simulation loop
//! ([`crate::netsim::World::run_with_churn`]): the world runs to each
//! event's exact virtual time, the action is applied, and the run resumes
//! — so churn interleaves with packet delivery deterministically.

use super::{Time, MILLI};
use crate::util::Rng;

/// What happens to a node at a churn event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnAction {
    /// (Re)start the node and re-bootstrap it.
    Join,
    /// Clean stop: connections are closed with a goodbye before the node
    /// goes away.
    Leave,
    /// Crash: the node vanishes mid-flight; peers find out via timeouts.
    Crash,
}

/// One scheduled churn event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChurnEvent {
    pub at: Time,
    /// Scenario-level node index (not an endpoint id).
    pub node: usize,
    pub action: ChurnAction,
}

/// Parameters for [`ChurnPlan::poisson`].
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    /// Total node count in the scenario.
    pub nodes: usize,
    /// Nodes `[0, protected)` never churn (bootstrap peers, publishers).
    pub protected: usize,
    /// First event no earlier than this (lets the mesh settle).
    pub start: Time,
    /// No events at or after this time.
    pub end: Time,
    /// Median up-session length (exponential sessions: mean = h / ln 2).
    pub session_half_life: Time,
    /// Mean downtime before a node rejoins.
    pub downtime_mean: Time,
    /// Probability a departure is a crash rather than a clean leave.
    pub crash_fraction: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            nodes: 0,
            protected: 1,
            start: 10 * super::SECOND,
            end: 110 * super::SECOND,
            session_half_life: 60 * super::SECOND,
            downtime_mean: 10 * super::SECOND,
            crash_fraction: 0.5,
        }
    }
}

/// A fully materialized, time-ordered churn schedule.
#[derive(Clone, Debug, Default)]
pub struct ChurnPlan {
    events: Vec<ChurnEvent>,
    pos: usize,
}

impl ChurnPlan {
    /// No churn (the control arm of the bench/test harness).
    pub fn empty() -> ChurnPlan {
        ChurnPlan::default()
    }

    /// Generate a schedule of Poisson (exponential-session) churn. Pure
    /// function of `(cfg, seed)`.
    pub fn poisson(cfg: &ChurnConfig, seed: u64) -> ChurnPlan {
        let mut rng = Rng::new(seed ^ 0xC4_12_4E_5E_ED_00_01);
        let mean_up = cfg.session_half_life as f64 / std::f64::consts::LN_2;
        let mut events = Vec::new();
        for node in cfg.protected..cfg.nodes {
            let mut t = cfg.start;
            loop {
                // Up-session, then a departure…
                let up = rng.gen_exp(mean_up) as Time;
                t = t.saturating_add(up.max(MILLI));
                if t >= cfg.end {
                    break;
                }
                let action = if rng.gen_bool(cfg.crash_fraction) {
                    ChurnAction::Crash
                } else {
                    ChurnAction::Leave
                };
                events.push(ChurnEvent { at: t, node, action });
                // …then downtime and a rejoin.
                let down = rng.gen_exp(cfg.downtime_mean as f64) as Time;
                t = t.saturating_add(down.max(MILLI));
                if t >= cfg.end {
                    break;
                }
                events.push(ChurnEvent { at: t, node, action: ChurnAction::Join });
            }
        }
        events.sort_by_key(|e| (e.at, e.node));
        ChurnPlan { events, pos: 0 }
    }

    /// The full trace (determinism checks, debugging).
    pub fn events(&self) -> &[ChurnEvent] {
        &self.events
    }

    /// Next event not yet consumed.
    pub fn peek(&self) -> Option<&ChurnEvent> {
        self.events.get(self.pos)
    }

    /// Consume the next event if it is due at or before `now`.
    pub fn pop_due(&mut self, now: Time) -> Option<ChurnEvent> {
        match self.events.get(self.pos) {
            Some(e) if e.at <= now => {
                self.pos += 1;
                Some(*e)
            }
            _ => None,
        }
    }

    pub fn remaining(&self) -> usize {
        self.events.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// FNV-1a fingerprint of the trace — a cheap equality witness for the
    /// "same seed ⇒ same trace" contract.
    pub fn trace_digest(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        for e in &self.events {
            mix(e.at);
            mix(e.node as u64);
            mix(match e.action {
                ChurnAction::Join => 1,
                ChurnAction::Leave => 2,
                ChurnAction::Crash => 3,
            });
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::SECOND;

    fn cfg(n: usize) -> ChurnConfig {
        ChurnConfig {
            nodes: n,
            protected: 1,
            start: 5 * SECOND,
            end: 120 * SECOND,
            session_half_life: 30 * SECOND,
            downtime_mean: 8 * SECOND,
            crash_fraction: 0.5,
        }
    }

    #[test]
    fn same_seed_same_trace() {
        let a = ChurnPlan::poisson(&cfg(40), 7);
        let b = ChurnPlan::poisson(&cfg(40), 7);
        assert_eq!(a.events(), b.events());
        assert_eq!(a.trace_digest(), b.trace_digest());
        let c = ChurnPlan::poisson(&cfg(40), 8);
        assert_ne!(a.trace_digest(), c.trace_digest());
    }

    #[test]
    fn trace_is_time_ordered_and_alternating() {
        let plan = ChurnPlan::poisson(&cfg(30), 11);
        assert!(!plan.is_empty());
        let mut last = 0;
        for e in plan.events() {
            assert!(e.at >= last, "events must be time-ordered");
            last = e.at;
            assert!(e.node >= 1 && e.node < 30, "protected node churned");
        }
        // Per node: strictly alternating Leave/Crash → Join → Leave/Crash…
        for node in 1..30 {
            let mut up = true;
            for e in plan.events().iter().filter(|e| e.node == node) {
                match e.action {
                    ChurnAction::Join => {
                        assert!(!up, "join while up");
                        up = true;
                    }
                    ChurnAction::Leave | ChurnAction::Crash => {
                        assert!(up, "departure while down");
                        up = false;
                    }
                }
            }
        }
    }

    #[test]
    fn session_half_life_is_respected() {
        // Median of the generated up-session lengths ≈ configured half-life.
        let c = ChurnConfig {
            nodes: 400,
            end: 1000 * SECOND,
            ..cfg(400)
        };
        let plan = ChurnPlan::poisson(&c, 3);
        let mut sessions: Vec<Time> = Vec::new();
        for node in c.protected..c.nodes {
            let mut session_start = c.start;
            for e in plan.events().iter().filter(|e| e.node == node) {
                match e.action {
                    ChurnAction::Join => session_start = e.at,
                    _ => sessions.push(e.at - session_start),
                }
            }
        }
        assert!(sessions.len() > 1000, "need a large sample");
        sessions.sort_unstable();
        let median = sessions[sessions.len() / 2] as f64;
        let want = c.session_half_life as f64;
        assert!(
            (median - want).abs() / want < 0.1,
            "median session {median} vs half-life {want}"
        );
    }

    #[test]
    fn pop_due_consumes_in_order() {
        let mut plan = ChurnPlan::poisson(&cfg(20), 5);
        let total = plan.len();
        let mut got = 0;
        while let Some(next) = plan.peek().copied() {
            assert!(plan.pop_due(next.at.saturating_sub(1)).is_none());
            let e = plan.pop_due(next.at).unwrap();
            assert_eq!(e, next);
            got += 1;
        }
        assert_eq!(got, total);
        assert_eq!(plan.remaining(), 0);
    }
}
