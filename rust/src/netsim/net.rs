//! The network core: virtual clock, event queue, datagram routing through
//! NATs and shapers, port bindings and timers.
//!
//! Listener lookup is a per-host sorted port table (`HostState::ports`)
//! rather than a global `HashMap<SimAddr, EndpointId>`: at planet scale the
//! lookup array for one host is a handful of entries probed by binary
//! search in cache, and the per-host tables are freed wholesale when a
//! scenario drops its world — no global map rehashing at 100k bindings.

use super::event::{EventKind, EventQueue};
use super::nat::NatBox;
use super::topology::{HostState, TopologyBuilder};
use super::Time;
use crate::multiaddr::SimAddr;
use crate::util::Rng;

/// Handle to a registered endpoint (a node's datagram stack).
///
/// Packs a 32-bit slot index and a 32-bit generation (see
/// `netsim::world`); treat it as opaque.
pub type EndpointId = usize;

/// A timer handle: `(endpoint, token)` pairs are delivered back to the
/// endpoint; cancellation is by generation counters in the endpoint logic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Timer {
    pub token: u64,
    pub at: Time,
}

/// Aggregate network statistics.
#[derive(Default, Debug, Clone)]
pub struct NetStats {
    pub datagrams_sent: u64,
    pub datagrams_delivered: u64,
    pub datagrams_lost: u64,
    pub datagrams_dropped_queue: u64,
    pub datagrams_dropped_nat: u64,
    pub datagrams_no_listener: u64,
    pub bytes_sent: u64,
    pub events_processed: u64,
    pub timer_events: u64,
    pub deliver_events: u64,
    /// Events whose destination endpoint was tombstoned before dispatch
    /// (O(1) removal leaves stale events in the queue; they are dropped
    /// here and counted).
    pub events_dropped_stale: u64,
    /// High-water mark of the event-queue depth (timers + in-flight
    /// datagrams). The memory-boundedness gauge for scale scenarios.
    pub peak_queue_depth: u64,
    /// Datagram deliveries currently sitting in the queue (in flight on
    /// the virtual wire), and its high-water mark.
    pub inflight_datagrams: u64,
    pub peak_inflight_datagrams: u64,
    /// Payload bytes held by in-flight deliveries, and its high-water
    /// mark — directly bounds event-queue heap usage.
    pub inflight_payload_bytes: u64,
    pub peak_inflight_payload_bytes: u64,
}

/// The simulated network. See module docs.
pub struct Net {
    pub(crate) queue: EventQueue,
    now: Time,
    pub rng: Rng,
    hosts: Vec<HostState>,
    nats: Vec<NatBox>,
    paths: Vec<Vec<super::link::PathProfile>>,
    loopback: super::link::PathProfile,
    pub stats: NetStats,
    /// Maximum simulated datagram size; larger sends panic (transports must
    /// fragment). Mirrors a ~1500-byte MTU with headroom for headers.
    pub mtu: usize,
}

impl Net {
    pub(crate) fn from_topology(t: TopologyBuilder, seed: u64) -> Net {
        Net {
            queue: EventQueue::with_kind(t.queue_kind),
            now: 0,
            rng: Rng::new(seed),
            hosts: t.hosts,
            nats: t.nats,
            paths: t.paths,
            loopback: t.loopback,
            stats: NetStats::default(),
            mtu: 1400,
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub(crate) fn set_now(&mut self, t: Time) {
        debug_assert!(t >= self.now);
        self.now = t;
    }

    pub fn host_count(&self) -> usize {
        self.hosts.len()
    }

    /// The NAT a host sits behind, if any.
    pub fn host_nat(&self, host: u32) -> Option<usize> {
        self.hosts[host as usize].cfg.nat
    }

    /// NAT type behind which `host` sits (None = public).
    pub fn host_nat_type(&self, host: u32) -> Option<super::nat::NatType> {
        self.host_nat(host).map(|n| self.nats[n].nat_type)
    }

    /// Whether `host` is a NAT's public face. Protocols use this as the
    /// sim stand-in for an AutoNAT dial-back verdict: an address observed
    /// from behind a NAT is a translated mapping, not a dialable listen
    /// address.
    pub fn is_nat_face(&self, host: u32) -> bool {
        self.hosts
            .get(host as usize)
            .is_some_and(|h| h.nat_face.is_some())
    }

    /// Endpoint listening on `addr`, if any (binary search in the host's
    /// sorted port table).
    fn listener(&self, addr: SimAddr) -> Option<EndpointId> {
        let h = self.hosts.get(addr.host as usize)?;
        h.ports
            .binary_search_by_key(&addr.port, |&(p, _)| p)
            .ok()
            .map(|i| h.ports[i].1)
    }

    /// Bind an endpoint to a concrete port on a host.
    pub fn bind(&mut self, endpoint: EndpointId, addr: SimAddr) -> anyhow::Result<()> {
        anyhow::ensure!(
            (addr.host as usize) < self.hosts.len(),
            "bind: unknown host {}",
            addr.host
        );
        anyhow::ensure!(
            self.hosts[addr.host as usize].nat_face.is_none(),
            "bind: host {} is a NAT public face",
            addr.host
        );
        let ports = &mut self.hosts[addr.host as usize].ports;
        match ports.binary_search_by_key(&addr.port, |&(p, _)| p) {
            Ok(_) => anyhow::bail!("bind: address {addr} already bound"),
            Err(i) => ports.insert(i, (addr.port, endpoint)),
        }
        Ok(())
    }

    /// Bind to an ephemeral port; returns the address.
    pub fn bind_ephemeral(&mut self, endpoint: EndpointId, host: u32) -> SimAddr {
        loop {
            let h = &mut self.hosts[host as usize];
            let port = h.next_ephemeral;
            h.next_ephemeral = h.next_ephemeral.checked_add(1).unwrap_or(49_152);
            if let Err(i) = h.ports.binary_search_by_key(&port, |&(p, _)| p) {
                h.ports.insert(i, (port, endpoint));
                return SimAddr::new(host, port);
            }
        }
    }

    pub fn unbind(&mut self, addr: SimAddr) {
        if let Some(h) = self.hosts.get_mut(addr.host as usize) {
            if let Ok(i) = h.ports.binary_search_by_key(&addr.port, |&(p, _)| p) {
                h.ports.remove(i);
            }
        }
    }

    /// Record a queue push for the depth high-water mark.
    #[inline]
    fn note_push(&mut self) {
        let depth = self.queue.len() as u64;
        if depth > self.stats.peak_queue_depth {
            self.stats.peak_queue_depth = depth;
        }
    }

    /// A queued delivery left the queue (dispatched or dropped as stale):
    /// release its in-flight accounting. Called by the world's run loop.
    #[inline]
    pub(crate) fn note_payload_released(&mut self, len: usize) {
        self.stats.inflight_datagrams = self.stats.inflight_datagrams.saturating_sub(1);
        self.stats.inflight_payload_bytes =
            self.stats.inflight_payload_bytes.saturating_sub(len as u64);
    }

    /// Send a datagram from a bound local address to a destination address.
    ///
    /// Performs outbound NAT translation at the sender, routing, inbound NAT
    /// translation at the receiver, link shaping and loss. Delivery (if any)
    /// is scheduled on the event queue.
    pub fn send(&mut self, from: SimAddr, to: SimAddr, payload: Vec<u8>) {
        let size = payload.len() + 28; // UDP+IP header overhead
        assert!(
            payload.len() <= self.mtu,
            "datagram exceeds MTU: {} > {} (transports must fragment)",
            payload.len(),
            self.mtu
        );
        self.stats.datagrams_sent += 1;
        self.stats.bytes_sent += size as u64;
        let now = self.now;

        // 1. Outbound NAT translation at the sender. Destinations that are
        //    another NAT's public face mark the flow as a punch (see
        //    `NatBox::translate_outbound`).
        let src_host = from.host;
        let dst_face = self
            .hosts
            .get(to.host as usize)
            .and_then(|h| h.nat_face);
        let public_src = match self.hosts[src_host as usize].cfg.nat {
            Some(nat_id) => {
                let nat = &mut self.nats[nat_id];
                nat.translate_outbound(now, from, to, dst_face.is_some(), &mut self.rng)
            }
            None => from,
        };

        // 2. Route: is the destination a NAT public face?
        let (internal_dst, dst_host) = match dst_face {
            Some(nat_id) => {
                // Hairpin check: sender behind the same NAT.
                let same_nat = self.hosts[src_host as usize].cfg.nat == Some(nat_id);
                if same_nat && !self.nats[nat_id].hairpin {
                    self.stats.datagrams_dropped_nat += 1;
                    return;
                }
                match self.nats[nat_id].translate_inbound(now, public_src, to) {
                    Some(internal) => (internal, internal.host),
                    None => {
                        self.stats.datagrams_dropped_nat += 1;
                        return;
                    }
                }
            }
            None => {
                // An internal address behind a NAT is not routable from
                // outside its own LAN — only the translated face is. (This
                // is what makes AutoNAT dial-backs to a private bind
                // address fail, flipping the node's status to Private.)
                if let Some(dst_nat) = self.hosts.get(to.host as usize).and_then(|h| h.cfg.nat) {
                    let same_lan =
                        src_host == to.host || self.hosts[src_host as usize].cfg.nat == Some(dst_nat);
                    if !same_lan {
                        self.stats.datagrams_dropped_nat += 1;
                        return;
                    }
                }
                (to, to.host)
            }
        };

        // 3. Listener lookup.
        let Some(endpoint) = self.listener(internal_dst) else {
            self.stats.datagrams_no_listener += 1;
            return;
        };

        // 4. Shaping + propagation. Every packet pays the per-host stack
        //    (CPU/kernel) cost on both ends; cross-host traffic additionally
        //    pays NIC serialization and propagation. Same-host traffic
        //    shares one stack shaper — which is why "Local" throughput in
        //    Table 1 is CPU-bound, not wire-bound.
        let arrive = if src_host == dst_host {
            let Some(depart) = self.hosts[src_host as usize].lo.enqueue(now, size) else {
                self.stats.datagrams_dropped_queue += 1;
                return;
            };
            let prop = match self.loopback.sample(&mut self.rng) {
                Some(d) => d,
                None => {
                    self.stats.datagrams_lost += 1;
                    return;
                }
            };
            // Receive-side stack cost (same shared shaper).
            let Some(arrive) = self.hosts[src_host as usize].lo.enqueue(depart + prop, size)
            else {
                self.stats.datagrams_dropped_queue += 1;
                return;
            };
            arrive
        } else {
            let Some(cpu_out) = self.hosts[src_host as usize].lo.enqueue(now, size) else {
                self.stats.datagrams_dropped_queue += 1;
                return;
            };
            let Some(depart_up) = self.hosts[src_host as usize].uplink.enqueue(cpu_out, size)
            else {
                self.stats.datagrams_dropped_queue += 1;
                return;
            };
            let ra = self.hosts[src_host as usize].cfg.region;
            let rb = self.hosts[dst_host as usize].cfg.region;
            let prof = self.paths[ra][rb];
            let Some(prop) = prof.sample(&mut self.rng) else {
                self.stats.datagrams_lost += 1;
                return;
            };
            let at_receiver = depart_up + prop;
            let Some(off_wire) = self.hosts[dst_host as usize]
                .downlink
                .enqueue(at_receiver, size)
            else {
                self.stats.datagrams_dropped_queue += 1;
                return;
            };
            // Receive-side stack cost.
            let Some(arrive) = self.hosts[dst_host as usize].lo.enqueue(off_wire, size) else {
                self.stats.datagrams_dropped_queue += 1;
                return;
            };
            arrive
        };

        self.stats.inflight_datagrams += 1;
        self.stats.inflight_payload_bytes += payload.len() as u64;
        if self.stats.inflight_datagrams > self.stats.peak_inflight_datagrams {
            self.stats.peak_inflight_datagrams = self.stats.inflight_datagrams;
        }
        if self.stats.inflight_payload_bytes > self.stats.peak_inflight_payload_bytes {
            self.stats.peak_inflight_payload_bytes = self.stats.inflight_payload_bytes;
        }
        self.queue.push(
            arrive,
            EventKind::Deliver {
                dst_endpoint: endpoint,
                from: public_src,
                to: internal_dst,
                payload,
            },
        );
        self.note_push();
    }

    /// Arm a timer; it fires on the owning endpoint after `delay`.
    pub fn set_timer(&mut self, endpoint: EndpointId, delay: Time, token: u64) {
        self.queue.push(
            self.now + delay,
            EventKind::Timer { endpoint, token },
        );
        self.note_push();
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::topology::LinkProfile;
    use crate::netsim::{MILLI, SECOND};

    fn two_public_hosts() -> (Net, u32, u32) {
        let mut t = TopologyBuilder::paper_regions();
        let a = t.public_host(0, LinkProfile::UNLIMITED);
        let b = t.public_host(2, LinkProfile::UNLIMITED);
        (t.build(1), a, b)
    }

    #[test]
    fn send_schedules_delivery_with_propagation() {
        let (mut net, a, b) = two_public_hosts();
        net.bind(7, SimAddr::new(b, 4001)).unwrap();
        net.send(SimAddr::new(a, 1000), SimAddr::new(b, 4001), vec![1, 2, 3]);
        // One event queued, at >= 75 ms.
        assert_eq!(net.pending(), 1);
        let (at, kind) = net.queue.pop().unwrap();
        assert!(at >= 75 * MILLI && at < 80 * MILLI, "at = {at}");
        match kind {
            EventKind::Deliver {
                dst_endpoint,
                from,
                to,
                payload,
            } => {
                assert_eq!(dst_endpoint, 7);
                assert_eq!(from, SimAddr::new(a, 1000));
                assert_eq!(to, SimAddr::new(b, 4001));
                assert_eq!(payload, vec![1, 2, 3]);
            }
            other => panic!("unexpected event {other:?}"),
        }
    }

    #[test]
    fn unbound_destination_dropped() {
        let (mut net, a, b) = two_public_hosts();
        net.send(SimAddr::new(a, 1000), SimAddr::new(b, 9), vec![0]);
        assert_eq!(net.pending(), 0);
        assert_eq!(net.stats.datagrams_no_listener, 1);
    }

    #[test]
    fn nat_round_trip() {
        let mut t = TopologyBuilder::paper_regions();
        let server = t.public_host(0, LinkProfile::UNLIMITED);
        let nat = t.nat(1, super::super::nat::NatType::PortRestrictedCone, LinkProfile::UNLIMITED);
        let client = t.natted_host(nat, LinkProfile::UNLIMITED);
        let mut net = t.build(2);
        net.bind(0, SimAddr::new(server, 53)).unwrap();
        net.bind(1, SimAddr::new(client, 5000)).unwrap();

        // Client → server: server sees the NAT's public address.
        net.send(SimAddr::new(client, 5000), SimAddr::new(server, 53), vec![1]);
        let (_, kind) = net.queue.pop().unwrap();
        let observed = match kind {
            EventKind::Deliver { from, .. } => from,
            _ => panic!(),
        };
        assert_ne!(observed.host, client);

        // Server → observed address: routes back to the client.
        net.send(SimAddr::new(server, 53), observed, vec![2]);
        let (_, kind) = net.queue.pop().unwrap();
        match kind {
            EventKind::Deliver { dst_endpoint, to, .. } => {
                assert_eq!(dst_endpoint, 1);
                assert_eq!(to, SimAddr::new(client, 5000));
            }
            _ => panic!(),
        }

        // A stranger cannot reach the mapping (port-restricted).
        t_public_extra(&mut net);
    }

    // Helper: sending from an unrelated (host,port) must be NAT-dropped.
    fn t_public_extra(net: &mut Net) {
        let before = net.stats.datagrams_dropped_nat;
        // Host 0 exists and is public; use an unrelated port.
        net.send(SimAddr::new(0, 9999), SimAddr::new(1, 20_000), vec![9]);
        // Either NAT-dropped or no-listener (if the port guess missed the
        // mapping); both count as "not delivered".
        assert!(net.stats.datagrams_dropped_nat + net.stats.datagrams_no_listener > before);
    }

    #[test]
    fn bandwidth_limits_throughput() {
        let mut t = TopologyBuilder::paper_regions();
        // 1 MB/s uplink.
        let slow = LinkProfile {
            up_bps: 1_000_000,
            down_bps: 0,
            ..LinkProfile::UNLIMITED
        };
        let a = t.public_host(0, slow);
        let b = t.public_host(0, LinkProfile::UNLIMITED);
        let mut net = t.build(3);
        net.bind(0, SimAddr::new(b, 1)).unwrap();
        // Send 100 × 1 KB ≈ 100 KB ⇒ last departure ≈ 100 ms ≫ propagation.
        // Queue cap is 50 ms ⇒ roughly half are dropped, and delivered ones
        // span ~50 ms of serialization.
        for _ in 0..100 {
            net.send(SimAddr::new(a, 2), SimAddr::new(b, 1), vec![0u8; 1000 - 28]);
        }
        let delivered = net.pending() as u64;
        assert!(net.stats.datagrams_dropped_queue > 0, "expected drop-tail");
        assert!(delivered >= 40 && delivered <= 70, "delivered = {delivered}");
        assert_eq!(net.stats.peak_queue_depth, delivered);
        assert_eq!(net.stats.peak_inflight_datagrams, delivered);
        assert!(net.stats.peak_inflight_payload_bytes >= delivered * (1000 - 28));
        // Last delivery time reflects ~1 ms per packet serialization.
        let mut last = 0;
        while let Some((at, _)) = net.queue.pop() {
            last = last.max(at);
        }
        assert!(last > 40 * MILLI && last < SECOND, "last = {last}");
    }

    #[test]
    fn ephemeral_binds_unique() {
        let (mut net, a, _) = two_public_hosts();
        let x = net.bind_ephemeral(0, a);
        let y = net.bind_ephemeral(0, a);
        assert_ne!(x, y);
        assert_eq!(x.host, a);
    }

    #[test]
    fn bind_unbind_rebind() {
        let (mut net, _, b) = two_public_hosts();
        net.bind(0, SimAddr::new(b, 80)).unwrap();
        net.unbind(SimAddr::new(b, 80));
        // Freed port is immediately rebindable to a new endpoint.
        net.bind(5, SimAddr::new(b, 80)).unwrap();
        net.bind(6, SimAddr::new(b, 79)).unwrap();
        net.bind(7, SimAddr::new(b, 81)).unwrap();
        assert_eq!(net.listener(SimAddr::new(b, 80)), Some(5));
        assert_eq!(net.listener(SimAddr::new(b, 79)), Some(6));
        assert_eq!(net.listener(SimAddr::new(b, 81)), Some(7));
        assert_eq!(net.listener(SimAddr::new(b, 82)), None);
    }

    #[test]
    fn double_bind_rejected() {
        let (mut net, a, _) = two_public_hosts();
        net.bind(0, SimAddr::new(a, 80)).unwrap();
        assert!(net.bind(1, SimAddr::new(a, 80)).is_err());
    }

    #[test]
    #[should_panic(expected = "exceeds MTU")]
    fn oversized_datagram_panics() {
        let (mut net, a, b) = two_public_hosts();
        net.bind(0, SimAddr::new(b, 1)).unwrap();
        net.send(SimAddr::new(a, 2), SimAddr::new(b, 1), vec![0u8; 20_000]);
    }
}
