//! Lightweight metrics: counters, histograms and rate meters used by the
//! bench harness to print the paper's tables.

use crate::netsim::Time;

/// Log-bucketed latency histogram (ns), p50/p95/p99 extraction.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Sorted samples (we keep raw values; volumes here are modest).
    samples: Vec<u64>,
    sorted: bool,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            samples: Vec::new(),
            sorted: true,
        }
    }

    pub fn record(&mut self, v: u64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_unstable();
            self.sorted = true;
        }
    }

    pub fn percentile(&mut self, p: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        self.ensure_sorted();
        let idx = ((self.samples.len() as f64 - 1.0) * p / 100.0).round() as usize;
        self.samples[idx]
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, o: &Histogram) {
        self.samples.extend_from_slice(&o.samples);
        self.sorted = false;
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
    }

    pub fn max(&mut self) -> u64 {
        self.ensure_sorted();
        *self.samples.last().unwrap_or(&0)
    }

    pub fn summary(&mut self) -> String {
        format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.len(),
            crate::util::timefmt::fmt_ns(self.mean() as u64),
            crate::util::timefmt::fmt_ns(self.percentile(50.0)),
            crate::util::timefmt::fmt_ns(self.percentile(95.0)),
            crate::util::timefmt::fmt_ns(self.percentile(99.0)),
            crate::util::timefmt::fmt_ns(self.max()),
        )
    }
}

/// Snapshot of one connection's transport health (congestion control,
/// loss recovery, pacing). Produced by `Connection::stats`, aggregated by
/// [`TransportHealth`], and surfaced in the bench JSON so the perf
/// trajectory can attribute regressions to the transport.
#[derive(Clone, Copy, Debug)]
pub struct TransportStats {
    /// Congestion-controller name ("fixed" | "newreno" | "cubic").
    pub cc: &'static str,
    /// Effective congestion window in bytes.
    pub cwnd: u64,
    /// Smoothed RTT.
    pub srtt: Time,
    /// Bytes currently in flight.
    pub inflight: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    pub bytes_retransmitted: u64,
    pub packets_retransmitted: u64,
    /// Loss rounds (fast retransmit + RTO).
    pub loss_events: u64,
    pub fast_retransmits: u64,
    pub rto_events: u64,
    /// Wire bytes spent on ACK frames (control-plane accounting).
    pub ack_bytes_sent: u64,
    /// ACK frames whose range list was cut to the per-frame cap.
    pub ack_truncations: u64,
    /// Share of send opportunities delayed by the pacer (0..1).
    pub pacer_utilization: f64,
}

/// Aggregate of [`TransportStats`] across a node's connections.
#[derive(Clone, Debug, Default)]
pub struct TransportHealth {
    pub conns: usize,
    cwnd_sum: u64,
    srtt_sum: Time,
    pacer_util_sum: f64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    pub bytes_retransmitted: u64,
    pub packets_retransmitted: u64,
    pub loss_events: u64,
    pub fast_retransmits: u64,
    pub rto_events: u64,
    pub ack_bytes_sent: u64,
    pub ack_truncations: u64,
}

impl TransportHealth {
    pub fn record(&mut self, s: &TransportStats) {
        self.conns += 1;
        self.cwnd_sum += s.cwnd;
        self.srtt_sum += s.srtt;
        self.pacer_util_sum += s.pacer_utilization;
        self.bytes_sent += s.bytes_sent;
        self.bytes_received += s.bytes_received;
        self.bytes_retransmitted += s.bytes_retransmitted;
        self.packets_retransmitted += s.packets_retransmitted;
        self.loss_events += s.loss_events;
        self.fast_retransmits += s.fast_retransmits;
        self.rto_events += s.rto_events;
        self.ack_bytes_sent += s.ack_bytes_sent;
        self.ack_truncations += s.ack_truncations;
    }

    pub fn mean_cwnd(&self) -> u64 {
        if self.conns == 0 {
            0
        } else {
            self.cwnd_sum / self.conns as u64
        }
    }

    pub fn mean_srtt(&self) -> Time {
        if self.conns == 0 {
            0
        } else {
            self.srtt_sum / self.conns as u64
        }
    }

    pub fn mean_pacer_utilization(&self) -> f64 {
        if self.conns == 0 {
            0.0
        } else {
            self.pacer_util_sum / self.conns as f64
        }
    }
}

/// Control-plane bytes by category vs application bytes delivered — the
/// "bytes of control per delivered byte" efficiency metric from the
/// control-plane compression work (DESIGN.md §Control-plane
/// compression). Aggregated across all nodes of a scenario; each
/// category counts encoded message bytes at the sender, so legacy and
/// compact encodings are compared on equal terms.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ControlPlaneStats {
    /// Transport ACK frame bytes (from `TransportStats::ack_bytes_sent`).
    pub ack_bytes: u64,
    /// Bitswap non-BLOCK message bytes (WANT/WANT_HAVE/HAVE/DONT_HAVE/
    /// CANCEL).
    pub bitswap_meta_bytes: u64,
    /// Gossip bytes (SUBSCRIBE/PUBLISH/IHAVE/IWANT — announcements are
    /// metadata from the sync pipeline's point of view).
    pub gossip_meta_bytes: u64,
    /// Kademlia request/reply bytes.
    pub kad_bytes: u64,
    /// Application payload bytes delivered (Bitswap block payloads).
    pub delivered_bytes: u64,
}

impl ControlPlaneStats {
    pub fn control_bytes(&self) -> u64 {
        self.ack_bytes + self.bitswap_meta_bytes + self.gossip_meta_bytes + self.kad_bytes
    }

    /// Control bytes per delivered byte; 0.0 when nothing was delivered.
    pub fn ratio(&self) -> f64 {
        if self.delivered_bytes == 0 {
            return 0.0;
        }
        self.control_bytes() as f64 / self.delivered_bytes as f64
    }

    pub fn merge(&mut self, o: &ControlPlaneStats) {
        self.ack_bytes += o.ack_bytes;
        self.bitswap_meta_bytes += o.bitswap_meta_bytes;
        self.gossip_meta_bytes += o.gossip_meta_bytes;
        self.kad_bytes += o.kad_bytes;
        self.delivered_bytes += o.delivered_bytes;
    }

    pub fn summary(&self) -> String {
        format!(
            "control={} (ack={} bitswap={} gossip={} kad={}) delivered={} ratio={:.4}",
            crate::util::timefmt::fmt_bytes(self.control_bytes()),
            crate::util::timefmt::fmt_bytes(self.ack_bytes),
            crate::util::timefmt::fmt_bytes(self.bitswap_meta_bytes),
            crate::util::timefmt::fmt_bytes(self.gossip_meta_bytes),
            crate::util::timefmt::fmt_bytes(self.kad_bytes),
            crate::util::timefmt::fmt_bytes(self.delivered_bytes),
            self.ratio(),
        )
    }
}

/// Circuit-relay health, both roles in one struct: the server-side
/// counters fill on nodes with `relay_enabled`, the client-side failover
/// counters fill on nodes whose relayed connections re-home after a relay
/// death. Snapshot via `Swarm::relay_stats`; the `nat_traversal` bench
/// emits per-relay egress from these.
#[derive(Clone, Debug, Default)]
pub struct RelayStats {
    // Server side.
    /// Circuits spliced (lifetime count).
    pub circuits_opened: u64,
    /// CONNECTs refused: circuit cap, egress budget, or no reservation.
    pub circuits_refused: u64,
    /// RESERVEs refused at the reservation cap.
    pub reservations_refused: u64,
    /// Inner-packet bytes forwarded across circuits.
    pub bytes_relayed: u64,
    // Client side.
    /// Re-home attempts started after a relay connection died.
    pub failovers_started: u64,
    /// Inner connections successfully rebound to a backup relay.
    pub failovers_completed: u64,
    /// Re-homes that ran out of candidate relays (inner conn torn down).
    pub failovers_failed: u64,
}

impl RelayStats {
    pub fn merge(&mut self, o: &RelayStats) {
        self.circuits_opened += o.circuits_opened;
        self.circuits_refused += o.circuits_refused;
        self.reservations_refused += o.reservations_refused;
        self.bytes_relayed += o.bytes_relayed;
        self.failovers_started += o.failovers_started;
        self.failovers_completed += o.failovers_completed;
        self.failovers_failed += o.failovers_failed;
    }
}

/// Aggregated DHT lookup outcomes under (optional) churn: success rate,
/// hop counts, latency and routing-staleness. Filled by the churn harness
/// in `benches/dht_lookup` / `tests/dht_churn` and emitted as a
/// `BENCH_dht_churn.json` row.
#[derive(Clone, Debug, Default)]
pub struct DhtLookupStats {
    pub attempted: u64,
    pub succeeded: u64,
    /// Lookups that finished (success or not) vs timed out entirely.
    pub finished: u64,
    /// Lookups whose issuing node left/crashed mid-query; excluded from
    /// the success rate (there is no one left to consume the result).
    pub aborted: u64,
    /// Answered requests per finished lookup.
    pub hops: Histogram,
    /// Virtual-time latency per finished lookup.
    pub latency: Histogram,
    /// Requests tracked (sent or dial-pending) across all nodes — the
    /// staleness denominator (from `kad::KadStats::requests_tracked`).
    pub requests_sent: u64,
    /// Requests that hit a dead/stale routing entry (timeout or failed
    /// dial) across all nodes.
    pub requests_stale: u64,
}

impl DhtLookupStats {
    pub fn record_lookup(&mut self, success: bool, hops: u32, latency: Time) {
        self.finished += 1;
        if success {
            self.succeeded += 1;
        }
        self.hops.record(hops as u64);
        self.latency.record(latency);
    }

    /// Fraction of non-aborted lookups that succeeded.
    pub fn success_rate(&self) -> f64 {
        let denom = self.attempted.saturating_sub(self.aborted);
        if denom == 0 {
            return 0.0;
        }
        self.succeeded as f64 / denom as f64
    }

    /// Fraction of issued requests that hit stale routing state.
    pub fn staleness(&self) -> f64 {
        if self.requests_sent == 0 {
            return 0.0;
        }
        self.requests_stale as f64 / self.requests_sent as f64
    }

    pub fn mean_hops(&self) -> f64 {
        self.hops.mean()
    }

    pub fn summary(&mut self) -> String {
        format!(
            "lookups={}/{} ({:.1}%, {} aborted) hops mean={:.1} p95={} lat p95={} staleness={:.1}%",
            self.succeeded,
            self.attempted.saturating_sub(self.aborted),
            self.success_rate() * 100.0,
            self.aborted,
            self.mean_hops(),
            self.hops.percentile(95.0),
            crate::util::timefmt::fmt_ns(self.latency.percentile(95.0)),
            self.staleness() * 100.0,
        )
    }
}

/// Lookup outcomes of one `scenarios::planet` arm: the scaling-curve
/// sample (nodes → hops / success rate) emitted into
/// `BENCH_dht_churn.json` alongside the churn rows.
#[derive(Clone, Debug, Default)]
pub struct PlanetScaleStats {
    /// Deployment size (cores + background nodes).
    pub nodes: u64,
    pub attempted: u64,
    pub succeeded: u64,
    /// Answered requests per finished lookup.
    pub hops: Histogram,
    /// Virtual-time latency per finished lookup.
    pub latency: Histogram,
}

impl PlanetScaleStats {
    pub fn record(&mut self, success: bool, hops: u32, latency: Time) {
        if success {
            self.succeeded += 1;
        }
        self.hops.record(hops as u64);
        self.latency.record(latency);
    }

    pub fn success_rate(&self) -> f64 {
        if self.attempted == 0 {
            return 0.0;
        }
        self.succeeded as f64 / self.attempted as f64
    }

    pub fn mean_hops(&self) -> f64 {
        self.hops.mean()
    }

    pub fn summary(&mut self) -> String {
        format!(
            "n={} lookups={}/{} ({:.1}%) hops mean={:.1} p95={} lat p95={}",
            self.nodes,
            self.succeeded,
            self.attempted,
            self.success_rate() * 100.0,
            self.mean_hops(),
            self.hops.percentile(95.0),
            crate::util::timefmt::fmt_ns(self.latency.percentile(95.0)),
        )
    }
}

/// Aggregated outcome of one model-distribution run (trainer + N
/// replicas × M checkpoint versions). Shared by `benches/model_sync` and
/// `tests/model_sync` so the CI-gated bars and the published rows measure
/// the same quantities: per-version trainer egress, per-replica sync
/// latency, and per-version bytes actually moved (the delta evidence).
#[derive(Clone, Debug, Default)]
pub struct SyncStats {
    pub replicas: u64,
    pub blob_bytes: u64,
    /// Trainer bytes served per checkpoint version.
    pub egress_per_version: Vec<u64>,
    /// Sum over replicas of bytes fetched, per checkpoint version.
    pub fetched_per_version: Vec<u64>,
    /// Per-replica sync latency samples (ns), all versions pooled.
    pub latency: Histogram,
}

impl SyncStats {
    pub fn record_version(&mut self, egress: u64, fetched: u64) {
        self.egress_per_version.push(egress);
        self.fetched_per_version.push(fetched);
    }

    /// Worst per-version trainer egress as a multiple of the blob size.
    pub fn max_egress_x_blob(&self) -> f64 {
        let max = self.egress_per_version.iter().copied().max().unwrap_or(0);
        if self.blob_bytes == 0 {
            return 0.0;
        }
        max as f64 / self.blob_bytes as f64
    }

    /// Mean trainer egress per checkpoint (bytes).
    pub fn mean_egress(&self) -> f64 {
        if self.egress_per_version.is_empty() {
            return 0.0;
        }
        self.egress_per_version.iter().sum::<u64>() as f64
            / self.egress_per_version.len() as f64
    }

    /// Fraction of the full demand (replicas × blob) actually moved for
    /// version index `v` — <1.0 is the delta savings.
    pub fn fetched_fraction(&self, v: usize) -> f64 {
        let demand = self.replicas.saturating_mul(self.blob_bytes);
        if demand == 0 {
            return 0.0;
        }
        self.fetched_per_version.get(v).copied().unwrap_or(0) as f64 / demand as f64
    }

    pub fn summary(&mut self) -> String {
        format!(
            "replicas={} blob={} egress/ckpt={} (max {:.2}x blob) sync p50={} p99={}",
            self.replicas,
            crate::util::timefmt::fmt_bytes(self.blob_bytes),
            crate::util::timefmt::fmt_bytes(self.mean_egress() as u64),
            self.max_egress_x_blob(),
            crate::util::timefmt::fmt_ns(self.latency.percentile(50.0)),
            crate::util::timefmt::fmt_ns(self.latency.percentile(99.0)),
        )
    }
}

/// Server-side service-layer counters ([`crate::rpc::ServiceRouter`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Unary requests answered `Ok` by a handler.
    pub served: u64,
    /// Unary requests a handler answered with a failure status.
    pub failed: u64,
    /// Handlers that took the reply handle for a later response.
    pub deferred: u64,
    /// Requests for a service nobody registered (answered `NotFound`).
    pub unknown_service: u64,
    /// Requests for an unregistered method (answered `NotFound`).
    pub unknown_method: u64,
    /// Requests dropped at dispatch because their deadline had passed.
    pub expired: u64,
    /// Stream items routed to stream handlers.
    pub stream_items: u64,
    /// Requests rejected by admission control before payload decode
    /// (answered `Overloaded` from the header alone).
    pub shed_predecode: u64,
}

impl RouterStats {
    pub fn summary(&self) -> String {
        format!(
            "served={} failed={} deferred={} unknown={}/{} expired={} stream_items={} shed_predecode={}",
            self.served,
            self.failed,
            self.deferred,
            self.unknown_service,
            self.unknown_method,
            self.expired,
            self.stream_items,
            self.shed_predecode,
        )
    }
}

/// Client-side stub counters ([`crate::rpc::Stub`]): one logical op can
/// fan out into several attempts via retries, hedges and failover.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StubStats {
    /// Logical calls issued.
    pub ops: u64,
    /// Logical calls that finished `Ok`.
    pub ok: u64,
    /// Logical calls that finished with a failure status.
    pub failed: u64,
    /// Wire attempts issued (≥ ops).
    pub attempts: u64,
    /// Attempts issued by the retry/backoff path.
    pub retries: u64,
    /// Speculative second attempts issued by the hedging path.
    pub hedges: u64,
    /// Ops won by the hedge attempt rather than the primary.
    pub hedge_wins: u64,
    /// Attempts sent to a different target than the previous attempt.
    pub failovers: u64,
    /// Attempts cancelled after another attempt won.
    pub cancelled: u64,
    /// Ops that exhausted their overall deadline.
    pub deadline_expired: u64,
    /// `Overloaded` responses received (server pushback).
    pub overloaded: u64,
    /// Hedges not issued (or abandoned) because a target signalled
    /// overload — speculative duplicates would amplify the saturation.
    pub hedges_suppressed: u64,
}

impl StubStats {
    pub fn summary(&self) -> String {
        format!(
            "ops={} ok={} failed={} attempts={} retries={} hedges={} (won {}, suppressed {}) failovers={} expired={} overloaded={}",
            self.ops,
            self.ok,
            self.failed,
            self.attempts,
            self.retries,
            self.hedges,
            self.hedge_wins,
            self.hedges_suppressed,
            self.failovers,
            self.deadline_expired,
            self.overloaded,
        )
    }
}

/// Completed-ops counter over a virtual-time window → QPS.
#[derive(Clone, Debug, Default)]
pub struct QpsMeter {
    pub completed: u64,
    pub started_at: Time,
    pub finished_at: Time,
}

impl QpsMeter {
    pub fn start(now: Time) -> QpsMeter {
        QpsMeter {
            completed: 0,
            started_at: now,
            finished_at: now,
        }
    }

    pub fn record(&mut self, now: Time) {
        self.completed += 1;
        self.finished_at = now;
    }

    /// Queries per (virtual) second.
    pub fn qps(&self) -> f64 {
        let dt = self.finished_at.saturating_sub(self.started_at);
        if dt == 0 {
            return 0.0;
        }
        self.completed as f64 * 1e9 / dt as f64
    }
}

/// Inference-plane counters ([`crate::route`]): KV-cache residency on
/// shard stages plus client-side serving latency. Shards and clients each
/// keep one; scenarios merge them for a fleet view.
#[derive(Clone, Debug, Default)]
pub struct InferenceStats {
    /// KV sessions created (first Open of a request on this stage).
    pub sessions_opened: u64,
    /// Sessions reset by a higher-generation Open (post-repair replay).
    pub sessions_reset: u64,
    /// Sessions dropped on stream close / request completion.
    pub sessions_closed: u64,
    /// Sessions evicted by the LRU capacity sweep.
    pub sessions_evicted: u64,
    /// Resident KV entries right now (gauge; entry = layer × position).
    pub kv_entries: u64,
    /// High-water mark of `kv_entries`.
    pub kv_peak: u64,
    /// Positions appended into resident state.
    pub kv_appends: u64,
    /// Appends dropped because the position was already resident. Zero in
    /// a correct run — replay uses generation resets, never re-appends.
    pub duplicate_appends: u64,
    /// Appends dropped for skipping ahead of the session.
    pub gap_drops: u64,
    /// Tokens emitted by a tail stage / acked by a client.
    pub tokens_streamed: u64,
    /// Chain repairs performed (client-side counter).
    pub repairs: u64,
    /// Fault frames forwarded upstream after a downstream death.
    pub faults_propagated: u64,
    /// Client-observed time-to-first-token.
    pub ttft: Histogram,
}

impl InferenceStats {
    pub fn merge(&mut self, o: &InferenceStats) {
        self.sessions_opened += o.sessions_opened;
        self.sessions_reset += o.sessions_reset;
        self.sessions_closed += o.sessions_closed;
        self.sessions_evicted += o.sessions_evicted;
        self.kv_entries += o.kv_entries;
        self.kv_peak += o.kv_peak;
        self.kv_appends += o.kv_appends;
        self.duplicate_appends += o.duplicate_appends;
        self.gap_drops += o.gap_drops;
        self.tokens_streamed += o.tokens_streamed;
        self.repairs += o.repairs;
        self.faults_propagated += o.faults_propagated;
        self.ttft.merge(&o.ttft);
    }

    pub fn summary(&mut self) -> String {
        format!(
            "sessions={} (reset {}, closed {}, evicted {}) kv_entries={} (peak {}) appends={} dup={} gaps={} tokens={} repairs={} faults={} ttft_p50={} ttft_p99={}",
            self.sessions_opened,
            self.sessions_reset,
            self.sessions_closed,
            self.sessions_evicted,
            self.kv_entries,
            self.kv_peak,
            self.kv_appends,
            self.duplicate_appends,
            self.gap_drops,
            self.tokens_streamed,
            self.repairs,
            self.faults_propagated,
            crate::util::timefmt::fmt_ns(self.ttft.percentile(50.0)),
            crate::util::timefmt::fmt_ns(self.ttft.percentile(99.0)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for v in (1..=100).rev() {
            h.record(v);
        }
        assert_eq!(h.len(), 100);
        let p50 = h.percentile(50.0);
        assert!((50..=51).contains(&p50), "p50={p50}");
        let p99 = h.percentile(99.0);
        assert!((99..=100).contains(&p99), "p99={p99}");
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn transport_health_aggregates() {
        let mut h = TransportHealth::default();
        assert_eq!(h.mean_cwnd(), 0);
        let s = TransportStats {
            cc: "cubic",
            cwnd: 1000,
            srtt: 10,
            inflight: 0,
            bytes_sent: 5,
            bytes_received: 6,
            bytes_retransmitted: 7,
            packets_retransmitted: 1,
            loss_events: 2,
            fast_retransmits: 1,
            rto_events: 1,
            ack_bytes_sent: 40,
            ack_truncations: 3,
            pacer_utilization: 0.5,
        };
        h.record(&s);
        h.record(&TransportStats { cwnd: 3000, pacer_utilization: 0.0, ..s });
        assert_eq!(h.conns, 2);
        assert_eq!(h.mean_cwnd(), 2000);
        assert_eq!(h.mean_srtt(), 10);
        assert_eq!(h.bytes_retransmitted, 14);
        assert_eq!(h.loss_events, 4);
        assert_eq!(h.ack_bytes_sent, 80);
        assert_eq!(h.ack_truncations, 6);
        assert!((h.mean_pacer_utilization() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn control_plane_ratio() {
        let mut c = ControlPlaneStats {
            ack_bytes: 100,
            bitswap_meta_bytes: 200,
            gossip_meta_bytes: 50,
            kad_bytes: 150,
            delivered_bytes: 0,
        };
        assert_eq!(c.control_bytes(), 500);
        assert_eq!(c.ratio(), 0.0, "no delivery → ratio degenerates to 0");
        c.delivered_bytes = 10_000;
        assert!((c.ratio() - 0.05).abs() < 1e-9);
        c.merge(&c.clone());
        assert_eq!(c.control_bytes(), 1000);
        assert_eq!(c.delivered_bytes, 20_000);
        assert!((c.ratio() - 0.05).abs() < 1e-9, "merge preserves the rate");
        assert!(!c.summary().is_empty());
    }

    #[test]
    fn dht_lookup_stats_rates() {
        let mut s = DhtLookupStats::default();
        assert_eq!(s.success_rate(), 0.0);
        assert_eq!(s.staleness(), 0.0);
        s.attempted = 4;
        s.record_lookup(true, 3, 1000);
        s.record_lookup(true, 5, 3000);
        s.record_lookup(false, 9, 9000);
        s.requests_sent = 20;
        s.requests_stale = 5;
        assert!((s.success_rate() - 0.5).abs() < 1e-9);
        assert!((s.staleness() - 0.25).abs() < 1e-9);
        assert!((s.mean_hops() - 17.0 / 3.0).abs() < 1e-9);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn sync_stats_ratios() {
        let mut s = SyncStats {
            replicas: 4,
            blob_bytes: 1000,
            ..SyncStats::default()
        };
        s.record_version(1500, 4000);
        s.record_version(900, 800);
        assert!((s.max_egress_x_blob() - 1.5).abs() < 1e-9);
        assert!((s.mean_egress() - 1200.0).abs() < 1e-9);
        assert!((s.fetched_fraction(0) - 1.0).abs() < 1e-9);
        assert!((s.fetched_fraction(1) - 0.2).abs() < 1e-9);
        s.latency.record(5);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn qps_meter() {
        let mut m = QpsMeter::start(0);
        for i in 1..=1000u64 {
            m.record(i * 1_000_000); // one per ms
        }
        let qps = m.qps();
        assert!((qps - 1000.0).abs() < 1.0, "qps={qps}");
    }

    #[test]
    fn inference_stats_merge_and_summary() {
        let mut a = InferenceStats {
            sessions_opened: 2,
            kv_appends: 10,
            kv_entries: 40,
            kv_peak: 48,
            tokens_streamed: 6,
            ..InferenceStats::default()
        };
        a.ttft.record(5 * 1_000_000);
        let mut b = InferenceStats {
            sessions_opened: 1,
            sessions_evicted: 1,
            duplicate_appends: 2,
            repairs: 1,
            ..InferenceStats::default()
        };
        b.ttft.record(9 * 1_000_000);
        a.merge(&b);
        assert_eq!(a.sessions_opened, 3);
        assert_eq!(a.sessions_evicted, 1);
        assert_eq!(a.duplicate_appends, 2);
        assert_eq!(a.repairs, 1);
        assert_eq!(a.ttft.len(), 2);
        assert!(a.summary().contains("repairs=1"));
    }
}
