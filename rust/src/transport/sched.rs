//! Priority-aware stream scheduling.
//!
//! Streams carry a [`TrafficClass`] assigned when they are opened (the
//! upper layers pass one explicitly, or it is derived from the protocol
//! name). The packetizer drains classes in strict priority order —
//! control before unary RPC before streaming before bulk — and
//! round-robins among the streams of the winning class, so a multi-
//! megabyte Bitswap block transfer can no longer starve pings, DCUtR
//! probes or CRDT gossip on a congested uplink. Strict priority is safe
//! here because the high classes are intrinsically light (control frames
//! and request/response payloads); bulk always gets the leftover
//! bandwidth, which on a saturated link is most of it.
//!
//! Pure strict priority has one pathological corner: a class saturated
//! by its own load (e.g. unary RPC under an overload storm) would pin
//! lower classes at exactly zero forever. To keep the anti-starvation
//! guarantee symmetric, every [`SHARE_PERIOD`]-th serve is given to a
//! waiting lower class instead (cycling across them when several wait),
//! so lower classes always own ~1/16 of a saturated link — enough for
//! model-sync and gossip to creep forward while the overload lasts,
//! cheap enough to be noise when it doesn't.

use std::collections::{HashSet, VecDeque};

/// One serve in every `SHARE_PERIOD` goes to a waiting lower class even
/// while a higher class is saturated.
const SHARE_PERIOD: u64 = 16;

/// Priority class for a stream, highest first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TrafficClass {
    /// Liveness, signalling, discovery, relay control.
    Control = 0,
    /// Request/response RPC.
    Unary = 1,
    /// Long-lived tensor/item streams, gossip.
    Streaming = 2,
    /// Background block transfer (model sync, CDN fill).
    Bulk = 3,
}

impl TrafficClass {
    pub const COUNT: usize = 4;

    pub fn name(&self) -> &'static str {
        match self {
            TrafficClass::Control => "control",
            TrafficClass::Unary => "unary",
            TrafficClass::Streaming => "streaming",
            TrafficClass::Bulk => "bulk",
        }
    }

    /// Default class for a protocol name (used when the opener did not
    /// pass one explicitly, and on the accepting side of a stream).
    pub fn for_proto(proto: &str) -> TrafficClass {
        const CONTROL: [&str; 7] = [
            "/lattica/ping/",
            "/lattica/identify/",
            "/lattica/autonat/",
            "/lattica/rendezvous/",
            "/lattica/dcutr/",
            "/lattica/kad/",
            "/lattica/relay/",
        ];
        if proto.starts_with("/lattica/bitswap/") || proto.starts_with("/lattica/crdt/") {
            TrafficClass::Bulk
        } else if CONTROL.iter().any(|p| proto.starts_with(p)) {
            TrafficClass::Control
        } else if proto.starts_with("/lattica/rpc/") {
            TrafficClass::Unary
        } else {
            TrafficClass::Streaming
        }
    }
}

/// Active-stream queues, one per class; see module docs.
#[derive(Debug, Default)]
pub struct StreamScheduler {
    queues: [VecDeque<u64>; TrafficClass::COUNT],
    queued: HashSet<u64>,
    /// Chunks served so far (bumped by `rotate`); drives the periodic
    /// lower-class share.
    served: u64,
}

impl StreamScheduler {
    pub fn new() -> StreamScheduler {
        StreamScheduler::default()
    }

    /// Mark a stream as having pending data (idempotent).
    pub fn activate(&mut self, stream_id: u64, class: TrafficClass) {
        if self.queued.insert(stream_id) {
            self.queues[class as usize].push_back(stream_id);
        }
    }

    /// Class to serve next: the highest-priority non-empty queue, except
    /// that every `SHARE_PERIOD`-th serve goes to a waiting lower class
    /// (cycling across the lower classes when several are non-empty).
    /// Shared by `current`/`rotate`/`remove_current` so the three views
    /// of "the current stream" never disagree.
    fn current_class(&self) -> Option<usize> {
        let strict = self.queues.iter().position(|q| !q.is_empty())?;
        if (self.served + 1) % SHARE_PERIOD == 0 {
            let n_low = self.queues[strict + 1..]
                .iter()
                .filter(|q| !q.is_empty())
                .count();
            if n_low > 0 {
                let k = (self.served / SHARE_PERIOD) as usize % n_low;
                return self.queues[strict + 1..]
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| !q.is_empty())
                    .nth(k)
                    .map(|(i, _)| strict + 1 + i);
            }
        }
        Some(strict)
    }

    /// The stream to serve next; see [`StreamScheduler::current_class`].
    pub fn current(&self) -> Option<u64> {
        let c = self.current_class()?;
        self.queues[c].front().copied()
    }

    /// Rotate the current class's queue (round-robin fairness after the
    /// front stream contributed a chunk) and count the serve.
    pub fn rotate(&mut self) {
        if let Some(c) = self.current_class() {
            self.queues[c].rotate_left(1);
            self.served += 1;
        }
    }

    /// Drop the current stream from its queue (it had nothing sendable;
    /// it re-activates on new data, credit, or retransmission). Not a
    /// serve, so the share counter is untouched.
    pub fn remove_current(&mut self) {
        if let Some(c) = self.current_class() {
            if let Some(sid) = self.queues[c].pop_front() {
                self.queued.remove(&sid);
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.queued.is_empty()
    }

    /// All queued stream ids, priority order first.
    pub fn active_ids(&self) -> impl Iterator<Item = &u64> {
        self.queues.iter().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proto_classification() {
        assert_eq!(TrafficClass::for_proto("/lattica/ping/1"), TrafficClass::Control);
        assert_eq!(TrafficClass::for_proto("/lattica/kad/1"), TrafficClass::Control);
        assert_eq!(TrafficClass::for_proto("/lattica/relay/1"), TrafficClass::Control);
        assert_eq!(TrafficClass::for_proto("/lattica/rpc/1"), TrafficClass::Unary);
        assert_eq!(TrafficClass::for_proto("/lattica/rpc-stream/1"), TrafficClass::Streaming);
        assert_eq!(TrafficClass::for_proto("/lattica/gossip/1"), TrafficClass::Streaming);
        assert_eq!(TrafficClass::for_proto("/lattica/bitswap/1"), TrafficClass::Bulk);
        assert_eq!(TrafficClass::for_proto("/lattica/crdt/1"), TrafficClass::Bulk);
        // Unknown protocols get best-effort streaming.
        assert_eq!(TrafficClass::for_proto("/test/echo/1"), TrafficClass::Streaming);
    }

    #[test]
    fn strict_priority_across_classes() {
        let mut s = StreamScheduler::new();
        s.activate(30, TrafficClass::Bulk);
        s.activate(10, TrafficClass::Control);
        s.activate(20, TrafficClass::Streaming);
        assert_eq!(s.current(), Some(10));
        s.remove_current();
        assert_eq!(s.current(), Some(20));
        s.remove_current();
        assert_eq!(s.current(), Some(30));
        s.remove_current();
        assert_eq!(s.current(), None);
        assert!(s.is_empty());
    }

    #[test]
    fn round_robin_within_class() {
        let mut s = StreamScheduler::new();
        s.activate(1, TrafficClass::Bulk);
        s.activate(2, TrafficClass::Bulk);
        s.activate(3, TrafficClass::Bulk);
        let mut order = Vec::new();
        for _ in 0..6 {
            order.push(s.current().unwrap());
            s.rotate();
        }
        assert_eq!(order, vec![1, 2, 3, 1, 2, 3]);
    }

    #[test]
    fn activation_is_idempotent() {
        let mut s = StreamScheduler::new();
        s.activate(7, TrafficClass::Unary);
        s.activate(7, TrafficClass::Unary);
        assert_eq!(s.current(), Some(7));
        s.remove_current();
        assert_eq!(s.current(), None);
    }

    #[test]
    fn bulk_gets_guaranteed_share_under_unary_saturation() {
        // A saturating Unary stream must not pin Bulk at zero: every
        // SHARE_PERIOD-th serve goes to the waiting lower class.
        let mut s = StreamScheduler::new();
        s.activate(1, TrafficClass::Unary);
        s.activate(2, TrafficClass::Bulk);
        let mut bulk_serves = 0;
        for _ in 0..64 {
            if s.current() == Some(2) {
                bulk_serves += 1;
            }
            s.rotate();
        }
        assert_eq!(bulk_serves, 64 / SHARE_PERIOD, "bulk owns ~1/16 of serves");
        // The share cycles across several waiting lower classes.
        let mut s = StreamScheduler::new();
        s.activate(1, TrafficClass::Unary);
        s.activate(2, TrafficClass::Streaming);
        s.activate(3, TrafficClass::Bulk);
        let mut low = Vec::new();
        for _ in 0..64 {
            if let Some(sid) = s.current() {
                if sid != 1 {
                    low.push(sid);
                }
            }
            s.rotate();
        }
        assert_eq!(low, vec![2, 3, 2, 3], "boost alternates across lower classes");
    }

    #[test]
    fn higher_class_preempts_mid_rotation() {
        let mut s = StreamScheduler::new();
        s.activate(1, TrafficClass::Bulk);
        s.activate(2, TrafficClass::Bulk);
        s.rotate();
        s.activate(9, TrafficClass::Control);
        assert_eq!(s.current(), Some(9), "control preempts bulk rotation");
        s.remove_current();
        assert_eq!(s.current(), Some(2));
    }
}
