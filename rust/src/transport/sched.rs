//! Priority-aware stream scheduling.
//!
//! Streams carry a [`TrafficClass`] assigned when they are opened (the
//! upper layers pass one explicitly, or it is derived from the protocol
//! name). The packetizer drains classes in strict priority order —
//! control before unary RPC before streaming before bulk — and
//! round-robins among the streams of the winning class, so a multi-
//! megabyte Bitswap block transfer can no longer starve pings, DCUtR
//! probes or CRDT gossip on a congested uplink. Strict priority is safe
//! here because the high classes are intrinsically light (control frames
//! and request/response payloads); bulk always gets the leftover
//! bandwidth, which on a saturated link is most of it.

use std::collections::{HashSet, VecDeque};

/// Priority class for a stream, highest first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TrafficClass {
    /// Liveness, signalling, discovery, relay control.
    Control = 0,
    /// Request/response RPC.
    Unary = 1,
    /// Long-lived tensor/item streams, gossip.
    Streaming = 2,
    /// Background block transfer (model sync, CDN fill).
    Bulk = 3,
}

impl TrafficClass {
    pub const COUNT: usize = 4;

    pub fn name(&self) -> &'static str {
        match self {
            TrafficClass::Control => "control",
            TrafficClass::Unary => "unary",
            TrafficClass::Streaming => "streaming",
            TrafficClass::Bulk => "bulk",
        }
    }

    /// Default class for a protocol name (used when the opener did not
    /// pass one explicitly, and on the accepting side of a stream).
    pub fn for_proto(proto: &str) -> TrafficClass {
        const CONTROL: [&str; 7] = [
            "/lattica/ping/",
            "/lattica/identify/",
            "/lattica/autonat/",
            "/lattica/rendezvous/",
            "/lattica/dcutr/",
            "/lattica/kad/",
            "/lattica/relay/",
        ];
        if proto.starts_with("/lattica/bitswap/") || proto.starts_with("/lattica/crdt/") {
            TrafficClass::Bulk
        } else if CONTROL.iter().any(|p| proto.starts_with(p)) {
            TrafficClass::Control
        } else if proto.starts_with("/lattica/rpc/") {
            TrafficClass::Unary
        } else {
            TrafficClass::Streaming
        }
    }
}

/// Active-stream queues, one per class; see module docs.
#[derive(Debug, Default)]
pub struct StreamScheduler {
    queues: [VecDeque<u64>; TrafficClass::COUNT],
    queued: HashSet<u64>,
}

impl StreamScheduler {
    pub fn new() -> StreamScheduler {
        StreamScheduler::default()
    }

    /// Mark a stream as having pending data (idempotent).
    pub fn activate(&mut self, stream_id: u64, class: TrafficClass) {
        if self.queued.insert(stream_id) {
            self.queues[class as usize].push_back(stream_id);
        }
    }

    /// The stream to serve next: front of the highest-priority non-empty
    /// class queue.
    pub fn current(&self) -> Option<u64> {
        self.queues.iter().find_map(|q| q.front().copied())
    }

    /// Rotate the current class's queue (round-robin fairness after the
    /// front stream contributed a chunk).
    pub fn rotate(&mut self) {
        if let Some(q) = self.queues.iter_mut().find(|q| !q.is_empty()) {
            q.rotate_left(1);
        }
    }

    /// Drop the current stream from its queue (it had nothing sendable;
    /// it re-activates on new data, credit, or retransmission).
    pub fn remove_current(&mut self) {
        if let Some(q) = self.queues.iter_mut().find(|q| !q.is_empty()) {
            if let Some(sid) = q.pop_front() {
                self.queued.remove(&sid);
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.queued.is_empty()
    }

    /// All queued stream ids, priority order first.
    pub fn active_ids(&self) -> impl Iterator<Item = &u64> {
        self.queues.iter().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proto_classification() {
        assert_eq!(TrafficClass::for_proto("/lattica/ping/1"), TrafficClass::Control);
        assert_eq!(TrafficClass::for_proto("/lattica/kad/1"), TrafficClass::Control);
        assert_eq!(TrafficClass::for_proto("/lattica/relay/1"), TrafficClass::Control);
        assert_eq!(TrafficClass::for_proto("/lattica/rpc/1"), TrafficClass::Unary);
        assert_eq!(TrafficClass::for_proto("/lattica/rpc-stream/1"), TrafficClass::Streaming);
        assert_eq!(TrafficClass::for_proto("/lattica/gossip/1"), TrafficClass::Streaming);
        assert_eq!(TrafficClass::for_proto("/lattica/bitswap/1"), TrafficClass::Bulk);
        assert_eq!(TrafficClass::for_proto("/lattica/crdt/1"), TrafficClass::Bulk);
        // Unknown protocols get best-effort streaming.
        assert_eq!(TrafficClass::for_proto("/test/echo/1"), TrafficClass::Streaming);
    }

    #[test]
    fn strict_priority_across_classes() {
        let mut s = StreamScheduler::new();
        s.activate(30, TrafficClass::Bulk);
        s.activate(10, TrafficClass::Control);
        s.activate(20, TrafficClass::Streaming);
        assert_eq!(s.current(), Some(10));
        s.remove_current();
        assert_eq!(s.current(), Some(20));
        s.remove_current();
        assert_eq!(s.current(), Some(30));
        s.remove_current();
        assert_eq!(s.current(), None);
        assert!(s.is_empty());
    }

    #[test]
    fn round_robin_within_class() {
        let mut s = StreamScheduler::new();
        s.activate(1, TrafficClass::Bulk);
        s.activate(2, TrafficClass::Bulk);
        s.activate(3, TrafficClass::Bulk);
        let mut order = Vec::new();
        for _ in 0..6 {
            order.push(s.current().unwrap());
            s.rotate();
        }
        assert_eq!(order, vec![1, 2, 3, 1, 2, 3]);
    }

    #[test]
    fn activation_is_idempotent() {
        let mut s = StreamScheduler::new();
        s.activate(7, TrafficClass::Unary);
        s.activate(7, TrafficClass::Unary);
        assert_eq!(s.current(), Some(7));
        s.remove_current();
        assert_eq!(s.current(), None);
    }

    #[test]
    fn higher_class_preempts_mid_rotation() {
        let mut s = StreamScheduler::new();
        s.activate(1, TrafficClass::Bulk);
        s.activate(2, TrafficClass::Bulk);
        s.rotate();
        s.activate(9, TrafficClass::Control);
        assert_eq!(s.current(), Some(9), "control preempts bulk rotation");
        s.remove_current();
        assert_eq!(s.current(), Some(2));
    }
}
