//! The connection state machine: handshake, reliability, streams.
//!
//! Sans-io: callers feed decoded [`Packet`]s and virtual-time ticks, then
//! drain encoded packets from [`Connection::poll_output`] and semantic
//! events from [`Connection::poll_event`]. The swarm layer owns address
//! routing; a connection never touches the network directly, which lets the
//! same machine run over direct datagrams or a relay circuit.

use super::cc::{CcAlgorithm, CongestionController};
use super::frame::{self, Frame};
use super::packet::Packet;
use super::pacer::Pacer;
use super::rtt::RttEstimator;
use super::sched::{StreamScheduler, TrafficClass};
use super::streams::{RecvStream, SendStream};
use super::TransportProfile;
use crate::crypto::noise::HandshakeState;
use crate::crypto::{aead, PublicKey};
use crate::identity::{Keypair, PeerId};
use crate::metrics::TransportStats;
use crate::netsim::{Time, MILLI};
use crate::util::buf::Buf;
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};

/// A packet this many below the delivery front is lost regardless of
/// timing (large flushes still share timestamps; this deep window cannot
/// be reordering).
const DEEP_REORDER_PACKETS: u64 = 64;

/// Connection role.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Client,
    Server,
}

/// Configuration knobs.
#[derive(Clone, Debug)]
pub struct ConnectionConfig {
    pub profile: TransportProfile,
    /// Maximum datagram payload (from the simulator MTU).
    pub mtu: usize,
    /// Hard upper bound on in-flight bytes; the congestion controller's
    /// window is clamped to this (relay tunnels pin it low).
    pub max_inflight: u64,
    /// Congestion-control algorithm.
    pub cc: CcAlgorithm,
    /// Token-bucket pacing of data packets (see [`super::pacer`]).
    pub pacing: bool,
    /// Fast-retransmit packet threshold: a packet this many below the
    /// delivery front (with a time margin) is declared lost.
    pub reorder_packets: u64,
    /// Send a PING if idle this long (keeps NAT mappings alive).
    pub keepalive: Option<Time>,
    /// Declare the connection dead after this much silence with data
    /// outstanding.
    pub idle_timeout: Time,
}

impl Default for ConnectionConfig {
    fn default() -> Self {
        ConnectionConfig {
            profile: TransportProfile::QUIC_LIKE,
            mtu: 1400,
            max_inflight: 16 << 20,
            cc: CcAlgorithm::Cubic,
            pacing: true,
            reorder_packets: 3,
            keepalive: Some(10 * crate::netsim::SECOND),
            idle_timeout: 30 * crate::netsim::SECOND,
        }
    }
}

/// Events surfaced to the swarm.
#[derive(Debug)]
pub enum ConnEvent {
    /// Handshake complete; the peer's static key is authenticated.
    Established { peer: PeerId, key: PublicKey },
    /// Remote opened a stream with the given protocol.
    StreamOpened { stream_id: u64, proto: String },
    /// A complete message arrived on a stream (zero-copy slice of the
    /// decrypted packet whenever the message fit in one segment).
    Msg { stream_id: u64, msg: Buf },
    /// Remote finished the stream cleanly (all data delivered).
    StreamFinished { stream_id: u64 },
    /// Remote reset the stream.
    StreamReset { stream_id: u64, error: String },
    /// A PATH_RESPONSE validated the probed path.
    PathValidated { token: u64 },
    /// Connection closed (by peer, error, or idle timeout).
    Closed { error: String },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// TCP-like: waiting for SYN/SYN-ACK exchange.
    TcpConnect,
    Handshaking,
    Established,
    Closed,
}

struct SentPacket {
    frames: Vec<Frame>,
    size: u64,
    sent_at: Time,
    ack_eliciting: bool,
}

/// What one ingested packet contained — the swarm uses this for path
/// migration decisions (DCUtR) and for answering path challenges on the
/// path they arrived from.
#[derive(Debug, Default)]
pub struct RxInfo {
    /// Packet authenticated and was processed.
    pub accepted: bool,
    /// PATH_RESPONSE tokens received (our probe succeeded).
    pub path_responses: Vec<u64>,
    /// PATH_CHALLENGE tokens received (peer probing us); the swarm answers
    /// via [`Connection::make_path_response`] on the arrival path.
    pub path_challenges: Vec<u64>,
    /// Whether the packet carried anything beyond probes/acks.
    pub has_app_frames: bool,
}

/// See module docs.
pub struct Connection {
    pub local_cid: u64,
    pub remote_cid: u64,
    pub role: Role,
    cfg: ConnectionConfig,
    state: State,
    hs: Option<HandshakeState>,
    hs_rng: Rng,
    keypair: Keypair,
    tx_key: Option<[u8; 32]>,
    rx_key: Option<[u8; 32]>,
    /// Peer identity, known after handshake.
    pub peer: Option<PeerId>,
    pub peer_key: Option<PublicKey>,

    next_pkt_num: u64,
    sent: BTreeMap<u64, SentPacket>,
    inflight: u64,
    rtt: RttEstimator,
    rto_backoff: u32,
    /// Congestion controller (owns the window; see `transport/cc.rs`).
    cc: Box<dyn CongestionController>,
    /// Token-bucket pacer for data packets.
    pacer: Pacer,
    /// RACK state: the newest delivered packet and when it was sent.
    largest_acked: Option<u64>,
    largest_acked_sent_at: Time,
    /// Start of the current loss round (counter bookkeeping mirrors the
    /// controller's once-per-round reduction rule).
    loss_round_start: Time,

    /// Received packet-number ranges (sorted, merged) for ACK generation.
    recv_ranges: Vec<(u64, u64)>,
    ack_eliciting_unacked: u32,
    /// Deadline for a delayed ACK (max_ack_delay after first unacked).
    ack_deadline: Option<Time>,

    send_streams: HashMap<u64, SendStream>,
    recv_streams: HashMap<u64, RecvStream>,
    /// Remote-opened streams whose STREAM_OPEN we have processed.
    remote_opened: std::collections::HashSet<u64>,
    /// Messages that arrived before the stream's STREAM_OPEN (reordering).
    early_msgs: HashMap<u64, Vec<Buf>>,
    /// Streams with pending data: per-class priority queues.
    scheduler: StreamScheduler,
    /// Stream id → traffic class (set at open on both sides).
    stream_classes: HashMap<u64, TrafficClass>,
    next_stream_id: u64,

    /// Control frames waiting to go out (handshake, opens, windows...).
    ctrl: VecDeque<Frame>,
    /// Encrypted packets that arrived before keys were ready.
    early_packets: Vec<Packet>,
    events: VecDeque<ConnEvent>,

    pub last_recv: Time,
    pub last_send: Time,
    created_at: Time,
    pub closed_reason: Option<String>,

    /// Stats for metrics/backpressure.
    pub bytes_sent: u64,
    pub bytes_received: u64,
    pub packets_retransmitted: u64,
    pub bytes_retransmitted: u64,
    /// Loss rounds (any recovery), fast-retransmit rounds, RTO rounds.
    pub loss_events: u64,
    pub fast_retransmits: u64,
    pub rto_events: u64,
    /// Wire bytes spent on ACK frames (control-plane accounting).
    pub ack_bytes_sent: u64,
    /// ACK frames built with the range list cut to the per-frame cap.
    pub ack_truncations: u64,
}

impl Connection {
    pub fn new(
        role: Role,
        cfg: ConnectionConfig,
        keypair: Keypair,
        now: Time,
        rng: &mut Rng,
    ) -> Connection {
        let local_cid = loop {
            let c = rng.next_u64();
            if c != 0 {
                break c;
            }
        };
        let hs_rng = rng.fork();
        let cc = cfg.cc.build(cfg.max_inflight);
        // Seed the bucket from the clamped window (the fixed controller
        // reports u64::MAX and relies on the max_inflight ceiling).
        let pacer = Pacer::new(now, cc.cwnd().clamp(super::cc::MIN_CWND, cfg.max_inflight));
        let mut conn = Connection {
            local_cid,
            remote_cid: 0,
            role,
            state: if role == Role::Client && cfg.profile.extra_handshake_rtts > 0 {
                State::TcpConnect
            } else {
                State::Handshaking
            },
            cfg,
            hs: None,
            hs_rng,
            keypair,
            tx_key: None,
            rx_key: None,
            peer: None,
            peer_key: None,
            next_pkt_num: 0,
            sent: BTreeMap::new(),
            inflight: 0,
            rtt: RttEstimator::new(),
            rto_backoff: 0,
            cc,
            pacer,
            largest_acked: None,
            largest_acked_sent_at: 0,
            loss_round_start: 0,
            recv_ranges: Vec::new(),
            ack_eliciting_unacked: 0,
            ack_deadline: None,
            send_streams: HashMap::new(),
            recv_streams: HashMap::new(),
            remote_opened: std::collections::HashSet::new(),
            early_msgs: HashMap::new(),
            scheduler: StreamScheduler::new(),
            stream_classes: HashMap::new(),
            next_stream_id: if role == Role::Client { 1 } else { 2 },
            ctrl: VecDeque::new(),
            early_packets: Vec::new(),
            events: VecDeque::new(),
            last_recv: now,
            last_send: now,
            created_at: now,
            closed_reason: None,
            bytes_sent: 0,
            bytes_received: 0,
            packets_retransmitted: 0,
            bytes_retransmitted: 0,
            loss_events: 0,
            fast_retransmits: 0,
            rto_events: 0,
            ack_bytes_sent: 0,
            ack_truncations: 0,
        };
        match (role, conn.state) {
            (Role::Client, State::TcpConnect) => conn.ctrl.push_back(Frame::syn()),
            (Role::Client, State::Handshaking) => conn.start_noise(),
            _ => {}
        }
        conn
    }

    fn start_noise(&mut self) {
        let mut hs = HandshakeState::initiator(self.keypair.secret().clone(), &mut self.hs_rng);
        let msg1 = hs.write_message(&[]).expect("noise msg1");
        self.hs = Some(hs);
        self.ctrl.push_back(Frame::handshake(1, msg1));
    }

    pub fn is_established(&self) -> bool {
        self.state == State::Established
    }

    pub fn is_closed(&self) -> bool {
        self.state == State::Closed
    }

    /// Application backlog across all streams (backpressure signal).
    pub fn backlog(&self) -> u64 {
        self.send_streams.values().map(|s| s.backlog()).sum::<u64>() + self.inflight
    }

    pub fn srtt(&self) -> Time {
        self.rtt.srtt()
    }

    /// Effective send window: the congestion controller's window clamped
    /// to the configured hard ceiling.
    pub fn window(&self) -> u64 {
        self.cc.cwnd().clamp(super::cc::MIN_CWND, self.cfg.max_inflight)
    }

    /// Transport-health snapshot for metrics export.
    pub fn stats(&self) -> TransportStats {
        TransportStats {
            cc: self.cc.name(),
            cwnd: self.window(),
            srtt: self.rtt.srtt(),
            inflight: self.inflight,
            bytes_sent: self.bytes_sent,
            bytes_received: self.bytes_received,
            bytes_retransmitted: self.bytes_retransmitted,
            packets_retransmitted: self.packets_retransmitted,
            loss_events: self.loss_events,
            fast_retransmits: self.fast_retransmits,
            rto_events: self.rto_events,
            ack_bytes_sent: self.ack_bytes_sent,
            ack_truncations: self.ack_truncations,
            pacer_utilization: self.pacer.utilization(),
        }
    }

    /// Tune for running inside a reliable tunnel (relay circuit): small
    /// window (the carrier has its own), long RTO floor (carrier queueing
    /// delay must not look like loss), and a deep reorder threshold so the
    /// carrier's own retransmissions never look like inner-path loss.
    pub fn tune_for_tunnel(&mut self) {
        self.cfg.max_inflight = 256 << 10;
        self.cfg.reorder_packets = DEEP_REORDER_PACKETS;
        // Rebuild the controller so its growth ceiling matches the new
        // clamp (called right after construction, before any traffic).
        self.cc = self.cfg.cc.build(self.cfg.max_inflight);
        self.rtt.initial_rto = 1_000 * MILLI;
        self.rtt.min_rto = 500 * MILLI;
        // An inner conn must outlive a dying relay conn: the relay path's
        // own (shorter) idle timeout fires first, parks this conn, and
        // re-homes it to a backup relay inside the grace window — instead
        // of both racing to the same 30 s deadline.
        self.cfg.idle_timeout *= 3;
    }

    /// Traffic class of a stream (default: best-effort streaming).
    fn class_of(&self, stream_id: u64) -> TrafficClass {
        self.stream_classes
            .get(&stream_id)
            .copied()
            .unwrap_or(TrafficClass::Streaming)
    }

    fn activate_stream(&mut self, stream_id: u64) {
        let class = self.class_of(stream_id);
        self.scheduler.activate(stream_id, class);
    }

    // ------------------------------------------------------------------
    // Stream API
    // ------------------------------------------------------------------

    /// Open an outbound stream for `proto`; usable immediately (frames queue
    /// until the handshake completes). The traffic class defaults from the
    /// protocol name.
    pub fn open_stream(&mut self, proto: &str) -> u64 {
        self.open_stream_class(proto, TrafficClass::for_proto(proto))
    }

    /// Open an outbound stream with an explicit scheduling class.
    pub fn open_stream_class(&mut self, proto: &str, class: TrafficClass) -> u64 {
        let id = self.next_stream_id;
        self.next_stream_id += 2;
        self.send_streams.insert(id, SendStream::new());
        self.recv_streams.insert(id, RecvStream::new());
        self.stream_classes.insert(id, class);
        self.ctrl.push_back(Frame::stream_open(id, proto));
        id
    }

    /// Queue a message on a stream (copies `msg` into the stream framing).
    pub fn send_msg(&mut self, stream_id: u64, msg: &[u8]) -> Result<()> {
        let s = self
            .send_streams
            .get_mut(&stream_id)
            .with_context(|| format!("unknown stream {stream_id}"))?;
        if s.closed || s.fin_queued {
            bail!("stream {stream_id} is closed for sending");
        }
        s.write_msg(msg);
        self.activate_stream(stream_id);
        Ok(())
    }

    /// Queue an owned message on a stream; large messages are queued
    /// zero-copy (the stream shares the buffer instead of copying it).
    pub fn send_msg_buf(&mut self, stream_id: u64, msg: Buf) -> Result<()> {
        let s = self
            .send_streams
            .get_mut(&stream_id)
            .with_context(|| format!("unknown stream {stream_id}"))?;
        if s.closed || s.fin_queued {
            bail!("stream {stream_id} is closed for sending");
        }
        s.write_msg_buf(msg);
        self.activate_stream(stream_id);
        Ok(())
    }

    /// Half-close: no more sends after queued data drains.
    pub fn finish_stream(&mut self, stream_id: u64) {
        if let Some(s) = self.send_streams.get_mut(&stream_id) {
            s.finish();
            self.activate_stream(stream_id);
        }
    }

    /// Abort a stream in both directions.
    pub fn reset_stream(&mut self, stream_id: u64, error: &str) {
        if let Some(s) = self.send_streams.get_mut(&stream_id) {
            s.closed = true;
            s.pending.clear();
        }
        if let Some(r) = self.recv_streams.get_mut(&stream_id) {
            r.reset = true;
        }
        self.ctrl.push_back(Frame::stream_reset(stream_id, error));
    }

    /// Initiate connection close.
    pub fn close(&mut self, error: &str) {
        if self.state != State::Closed {
            self.ctrl.push_back(Frame::conn_close(error));
            self.closed_reason = Some(error.to_string());
            // State flips to Closed after the close frame is flushed.
        }
    }

    /// Send a PATH_CHALLENGE (the swarm routes it via the probe path).
    pub fn make_path_challenge(&mut self, token: u64) -> Vec<u8> {
        let f = Frame::path_challenge(token);
        self.seal_packet(vec![f], true)
    }

    /// Answer a PATH_CHALLENGE (the swarm sends it on the arrival path).
    pub fn make_path_response(&mut self, token: u64) -> Vec<u8> {
        let f = Frame::path_response(token);
        self.seal_packet(vec![f], true)
    }

    pub fn send_ping(&mut self) {
        self.ctrl.push_back(Frame::ping());
    }

    // ------------------------------------------------------------------
    // Packet processing
    // ------------------------------------------------------------------

    /// Ingest one packet. Events/outputs are collected via the poll methods.
    pub fn handle_packet(&mut self, now: Time, pkt: Packet) -> Result<RxInfo> {
        let mut info = RxInfo::default();
        if self.state == State::Closed {
            return Ok(info);
        }
        self.last_recv = now;
        if self.remote_cid == 0 && pkt.src_cid != 0 {
            self.remote_cid = pkt.src_cid;
        }
        let pkt_num = pkt.pkt_num;
        let payload: Buf = if pkt.encrypted {
            if self.rx_key.is_none() {
                // Keys not ready (data raced ahead of handshake): stash.
                if self.early_packets.len() < 64 {
                    self.early_packets.push(pkt);
                }
                return Ok(info);
            }
            let k = self.rx_key.as_ref().unwrap();
            let ad = pkt.header_bytes();
            let nonce = pkt.nonce();
            let mut ct = pkt.payload;
            if ct.is_unique() {
                // Sole view of the datagram buffer: decrypt where the
                // bytes sit — no plaintext allocation or copy.
                let buf = ct.make_mut().expect("unique view");
                match aead::open_in_place_slice(k, &nonce, &ad, buf) {
                    Ok(n) => {
                        ct.truncate(n);
                        ct
                    }
                    Err(_) => {
                        // Unauthenticated packet: drop silently (could be
                        // a stale path probe or an attacker).
                        return Ok(info);
                    }
                }
            } else {
                // Shared view (relay-delivered): decrypt into a fresh buffer.
                match aead::open(k, &nonce, &ad, &ct) {
                    Ok(p) => Buf::from_vec(p),
                    Err(_) => return Ok(info),
                }
            }
        } else {
            if self.state == State::Established {
                // Plaintext after establishment is not acceptable (downgrade).
                return Ok(info);
            }
            // Reference-count bump, no copy.
            pkt.payload.clone()
        };
        info.accepted = true;
        self.bytes_received += payload.len() as u64;
        self.note_received(pkt_num);
        let frames = frame::decode_frames(&payload)?;
        let mut ack_eliciting = false;
        for f in frames {
            if f.is_ack_eliciting() {
                ack_eliciting = true;
            }
            match f.kind {
                frame::K_PATH_CHALLENGE => info.path_challenges.push(f.seq),
                frame::K_PATH_RESPONSE => info.path_responses.push(f.seq),
                frame::K_ACK | frame::K_PONG => {}
                _ => info.has_app_frames = true,
            }
            self.handle_frame(now, f)?;
        }
        if ack_eliciting {
            self.ack_eliciting_unacked += 1;
            if self.ack_deadline.is_none() {
                self.ack_deadline = Some(now + MILLI);
            }
        }
        // Drain early packets if keys just became available.
        if self.rx_key.is_some() && !self.early_packets.is_empty() {
            let early = std::mem::take(&mut self.early_packets);
            for p in early {
                let sub = self.handle_packet(now, p)?;
                info.path_responses.extend(sub.path_responses);
                info.path_challenges.extend(sub.path_challenges);
                info.has_app_frames |= sub.has_app_frames;
            }
        }
        Ok(info)
    }

    fn handle_frame(&mut self, now: Time, f: Frame) -> Result<()> {
        match f.kind {
            frame::K_SYN => {
                if self.role == Role::Server && self.state == State::TcpConnect
                    || self.state == State::Handshaking && self.hs.is_none()
                {
                    self.ctrl.push_back(Frame::syn_ack());
                }
            }
            frame::K_SYN_ACK => {
                if self.role == Role::Client && self.state == State::TcpConnect {
                    self.state = State::Handshaking;
                    self.start_noise();
                }
            }
            frame::K_HANDSHAKE => self.handle_handshake(f.seq, &f.data)?,
            frame::K_ACK => self.handle_ack(now, f.largest_ack, &f.ack_ranges),
            frame::K_STREAM_OPEN => {
                if !self.remote_opened.contains(&f.stream_id) {
                    self.remote_opened.insert(f.stream_id);
                    self.recv_streams.entry(f.stream_id).or_insert_with(RecvStream::new);
                    self.send_streams.entry(f.stream_id).or_insert_with(SendStream::new);
                    // Replies on this stream inherit the opener's class.
                    self.stream_classes
                        .entry(f.stream_id)
                        .or_insert_with(|| TrafficClass::for_proto(&f.proto));
                    self.events.push_back(ConnEvent::StreamOpened {
                        stream_id: f.stream_id,
                        proto: f.proto,
                    });
                    // Flush messages that raced ahead of the OPEN.
                    if let Some(buf) = self.early_msgs.remove(&f.stream_id) {
                        for m in buf {
                            self.events.push_back(ConnEvent::Msg {
                                stream_id: f.stream_id,
                                msg: m,
                            });
                        }
                    }
                }
            }
            frame::K_STREAM_DATA => {
                let r = self
                    .recv_streams
                    .entry(f.stream_id)
                    .or_insert_with(RecvStream::new);
                let (msgs, finished) = r.on_data(f.offset, f.data, f.fin)?;
                if let Some(limit) = r.credit_update() {
                    self.ctrl.push_back(Frame::stream_window(f.stream_id, limit));
                }
                // A locally opened stream has our id parity; a remote stream
                // must wait for its STREAM_OPEN before messages surface (the
                // OPEN carries the protocol name).
                let local_parity = (self.next_stream_id % 2) == 1;
                let is_local = (f.stream_id % 2 == 1) == local_parity;
                let open_known = is_local || self.remote_opened.contains(&f.stream_id);
                for m in msgs {
                    if open_known {
                        self.events.push_back(ConnEvent::Msg {
                            stream_id: f.stream_id,
                            msg: m,
                        });
                    } else {
                        self.early_msgs.entry(f.stream_id).or_default().push(m);
                    }
                }
                if finished {
                    self.events
                        .push_back(ConnEvent::StreamFinished { stream_id: f.stream_id });
                }
            }
            frame::K_STREAM_WINDOW => {
                if let Some(s) = self.send_streams.get_mut(&f.stream_id) {
                    s.credit_limit = s.credit_limit.max(f.credit);
                    if s.can_send() {
                        self.activate_stream(f.stream_id);
                    }
                }
            }
            frame::K_STREAM_RESET => {
                if let Some(r) = self.recv_streams.get_mut(&f.stream_id) {
                    r.reset = true;
                }
                if let Some(s) = self.send_streams.get_mut(&f.stream_id) {
                    s.closed = true;
                    s.pending.clear();
                }
                self.events.push_back(ConnEvent::StreamReset {
                    stream_id: f.stream_id,
                    error: f.error,
                });
            }
            frame::K_CONN_CLOSE => {
                self.state = State::Closed;
                self.closed_reason = Some(f.error.clone());
                self.events.push_back(ConnEvent::Closed { error: f.error });
            }
            frame::K_PING => self.ctrl.push_back(Frame::pong()),
            frame::K_PONG => {}
            frame::K_PATH_CHALLENGE => {
                // Answered by the swarm via make_path_response on the path
                // the challenge arrived from (see RxInfo).
            }
            frame::K_PATH_RESPONSE => {
                self.events.push_back(ConnEvent::PathValidated { token: f.seq });
            }
            _ => bail!("unhandled frame kind {}", f.kind),
        }
        Ok(())
    }

    fn handle_handshake(&mut self, idx: u64, data: &[u8]) -> Result<()> {
        match (self.role, idx) {
            (Role::Server, 1) => {
                if self.hs.is_some() || self.state == State::Established {
                    return Ok(()); // duplicate msg1 (retransmission)
                }
                let mut hs =
                    HandshakeState::responder(self.keypair.secret().clone(), &mut self.hs_rng);
                hs.read_message(data)?;
                let msg2 = hs.write_message(&[])?;
                self.hs = Some(hs);
                self.state = State::Handshaking;
                self.ctrl.push_back(Frame::handshake(2, msg2));
            }
            (Role::Client, 2) => {
                let Some(hs) = self.hs.as_mut() else {
                    return Ok(());
                };
                if hs.is_done() {
                    return Ok(()); // duplicate
                }
                hs.read_message(data)?;
                let msg3 = hs.write_message(&[])?;
                self.ctrl.push_back(Frame::handshake(3, msg3));
                self.finish_handshake()?;
            }
            (Role::Server, 3) => {
                let Some(hs) = self.hs.as_mut() else {
                    return Ok(());
                };
                if hs.is_done() {
                    return Ok(());
                }
                hs.read_message(data)?;
                self.finish_handshake()?;
            }
            _ => {} // stale/duplicate handshake frame
        }
        Ok(())
    }

    fn finish_handshake(&mut self) -> Result<()> {
        let hs = self.hs.take().context("no handshake state")?;
        let t = hs.into_transport()?;
        self.tx_key = Some(t.tx_key);
        self.rx_key = Some(t.rx_key);
        let peer = PeerId::from_public_key(&t.remote_static);
        self.peer = Some(peer);
        self.peer_key = Some(t.remote_static);
        self.state = State::Established;
        self.events.push_back(ConnEvent::Established {
            peer,
            key: t.remote_static,
        });
        Ok(())
    }

    // ------------------------------------------------------------------
    // ACK bookkeeping
    // ------------------------------------------------------------------

    fn note_received(&mut self, num: u64) {
        const MAX_RECV_RANGES: usize = 128;
        // Insert into merged ranges.
        let pos = self.recv_ranges.partition_point(|&(_, e)| e + 1 < num);
        if pos < self.recv_ranges.len() {
            let (s, e) = self.recv_ranges[pos];
            if num >= s && num <= e {
                return; // duplicate
            }
            if num + 1 == s {
                self.recv_ranges[pos].0 = num;
                self.merge_at(pos);
                return;
            }
            if num == e + 1 {
                self.recv_ranges[pos].1 = num;
                self.merge_at(pos);
                return;
            }
        }
        self.recv_ranges.insert(pos, (num, num));
        self.merge_at(pos);
        // Bound memory (duplicate-suppression window; wider than the
        // per-ACK-frame cap so late arrivals still dedupe).
        if self.recv_ranges.len() > MAX_RECV_RANGES {
            self.recv_ranges.remove(0);
        }
    }

    fn merge_at(&mut self, pos: usize) {
        if pos + 1 < self.recv_ranges.len() {
            let (s2, e2) = self.recv_ranges[pos + 1];
            let (_, e1) = self.recv_ranges[pos];
            if e1 + 1 >= s2 {
                self.recv_ranges[pos].1 = e1.max(e2);
                self.recv_ranges.remove(pos + 1);
            }
        }
        if pos > 0 {
            let (s1, e1) = self.recv_ranges[pos - 1];
            let (s2, e2) = self.recv_ranges[pos];
            if e1 + 1 >= s2 {
                self.recv_ranges[pos - 1] = (s1, e1.max(e2));
                self.recv_ranges.remove(pos);
            }
        }
    }

    /// Build an ACK frame from received ranges. Under heavy loss the
    /// range list can exceed what fits in one MTU, so the frame carries
    /// at most `MAX_ACK_RANGES` of the *most recent* (highest) ranges;
    /// dropped older ranges cost at worst a spurious retransmit, never
    /// correctness. Truncations are counted in `TransportStats`.
    fn make_ack(&mut self) -> Option<Frame> {
        const MAX_ACK_RANGES: usize = 32;
        let &(_, largest) = self.recv_ranges.last()?;
        let skip = self.recv_ranges.len().saturating_sub(MAX_ACK_RANGES);
        if skip > 0 {
            self.ack_truncations += 1;
        }
        let acked = &self.recv_ranges[skip..];
        // Encode alternating (run, gap) descending from largest.
        let mut ranges = Vec::with_capacity(acked.len() * 2);
        let mut prev_start = 0u64;
        for (i, &(s, e)) in acked.iter().rev().enumerate() {
            if i > 0 {
                ranges.push(prev_start - e - 1); // gap
            }
            ranges.push(e - s + 1); // run
            prev_start = s;
        }
        Some(Frame::ack(largest, ranges))
    }

    fn handle_ack(&mut self, now: Time, largest: u64, ranges: &[u64]) {
        // Decode ranges into (start, end) pairs descending.
        let mut acked_ranges: Vec<(u64, u64)> = Vec::new();
        let mut hi = largest;
        let mut it = ranges.iter();
        loop {
            let Some(&run) = it.next() else { break };
            let lo = hi.saturating_sub(run.saturating_sub(1));
            acked_ranges.push((lo, hi));
            let Some(&gap) = it.next() else { break };
            if lo < gap + 1 {
                break;
            }
            hi = lo - gap - 1;
        }
        if acked_ranges.is_empty() {
            acked_ranges.push((largest, largest));
        }
        let prior_inflight = self.inflight;
        let mut newly_acked = Vec::new();
        for &(lo, hi) in &acked_ranges {
            let keys: Vec<u64> = self.sent.range(lo..=hi).map(|(k, _)| *k).collect();
            for k in keys {
                if let Some(sp) = self.sent.remove(&k) {
                    self.inflight = self.inflight.saturating_sub(sp.size);
                    newly_acked.push((k, sp));
                }
            }
        }
        if let Some((num, sp)) = newly_acked.iter().max_by_key(|(k, _)| *k) {
            if *num == largest && sp.ack_eliciting {
                self.rtt.on_sample(now.saturating_sub(sp.sent_at));
            }
            // Advance the RACK delivery front.
            if self.largest_acked.map_or(true, |l| *num > l) {
                self.largest_acked = Some(*num);
                self.largest_acked_sent_at = sp.sent_at;
            }
        }
        if !newly_acked.is_empty() {
            self.rto_backoff = 0;
        }
        for (_, sp) in &newly_acked {
            self.cc.on_ack(now, sp.sent_at, sp.size, prior_inflight, &self.rtt);
        }
        self.detect_lost(now);
    }

    /// RACK-style loss detection, run on every ACK and timer tick. A
    /// packet behind the delivery front is lost when any of:
    ///
    /// * **deep gap** — `DEEP_REORDER_PACKETS` newer packets delivered
    ///   and a full srtt elapsed (at high send rates jitter alone reorders
    ///   hundreds of packets deep, so even this arm needs a time guard);
    /// * **spaced gap** — at least `reorder_packets` newer packets
    ///   delivered *and* the front was sent a reorder window after it
    ///   (the dup-ack fast-retransmit path, jitter-hardened: packets that
    ///   left in the same burst never trip it);
    /// * **tail time** — 9/8·srtt elapsed since it was sent while newer
    ///   packets were delivered (catches losses at the end of a flight
    ///   that no later packet can dup-ack). Floored at `min_rto` so relay
    ///   tunnels (which raise it) never mistake carrier queueing for loss.
    ///
    /// Recovery here never touches the RTO backoff: the ack clock is
    /// alive. The RTO in [`Connection::on_timer`] is the last resort for
    /// flights with no delivered successor at all.
    ///
    /// Every arm is monotone in packet number (the gap shrinks and
    /// `sent_at` is non-decreasing), so lost packets form a prefix of the
    /// range and the scan stops at the first survivor — a no-loss ACK
    /// inspects one packet.
    /// RACK tail-loss threshold; `next_timeout` arms a timer at exactly
    /// this delay past a packet's send time.
    fn tail_delay(&self) -> Time {
        let srtt = self.rtt.srtt();
        (srtt + srtt / 8).max(self.rtt.min_rto)
    }

    /// The backed-off retransmission timeout.
    fn backed_off_rto(&self) -> Time {
        self.rtt.rto() << self.rto_backoff.min(6)
    }

    fn detect_lost(&mut self, now: Time) {
        let Some(largest) = self.largest_acked else { return };
        let srtt = self.rtt.srtt();
        let reorder_time = srtt / 4;
        let tail_delay = self.tail_delay();
        let mut lost = Vec::new();
        for (&k, sp) in self.sent.range(..largest) {
            let gap = largest - k;
            let is_lost = (gap >= DEEP_REORDER_PACKETS
                && now.saturating_sub(sp.sent_at) >= srtt)
                || (gap >= self.cfg.reorder_packets
                    && self.largest_acked_sent_at >= sp.sent_at + reorder_time)
                || now >= sp.sent_at + tail_delay;
            if is_lost {
                lost.push(k);
            } else {
                break;
            }
        }
        if !lost.is_empty() {
            self.mark_lost(now, lost, false);
        }
    }

    /// Remove lost packets, requeue their frames, and notify the
    /// congestion controller once (it collapses a burst into one round).
    fn mark_lost(&mut self, now: Time, keys: Vec<u64>, persistent: bool) {
        let mut newest_sent = 0;
        let mut any = false;
        for k in keys {
            if let Some(sp) = self.sent.remove(&k) {
                any = true;
                newest_sent = newest_sent.max(sp.sent_at);
                self.inflight = self.inflight.saturating_sub(sp.size);
                self.bytes_retransmitted += sp.size;
                self.packets_retransmitted += 1;
                self.retransmit_frames(sp.frames);
            }
        }
        if any {
            // Losses of packets sent before the current round began are
            // the same round: count (and let the controller reduce) once.
            if persistent || newest_sent > self.loss_round_start {
                self.loss_round_start = now;
                self.loss_events += 1;
                if persistent {
                    self.rto_events += 1;
                } else {
                    self.fast_retransmits += 1;
                }
            }
            self.cc.on_loss(now, newest_sent, persistent, &self.rtt);
        }
    }

    fn retransmit_frames(&mut self, frames: Vec<Frame>) {
        for f in frames {
            if !f.is_retransmittable() {
                continue;
            }
            // Handshake-class frames are implicitly acknowledged by the
            // handshake completing; retransmitting them afterwards would
            // force a plaintext packet that an established peer rejects.
            if matches!(f.kind, frame::K_HANDSHAKE | frame::K_SYN | frame::K_SYN_ACK)
                && self.state == State::Established
            {
                continue;
            }
            match f.kind {
                frame::K_STREAM_DATA => {
                    let sid = f.stream_id;
                    if let Some(s) = self.send_streams.get_mut(&sid) {
                        s.requeue(f.offset, f.data, f.fin);
                        self.activate_stream(sid);
                    }
                }
                _ => self.ctrl.push_back(f),
            }
        }
    }

    // ------------------------------------------------------------------
    // Output
    // ------------------------------------------------------------------

    /// Whether the handshake allows sending encrypted app data.
    fn can_send_app(&self) -> bool {
        self.state == State::Established
    }

    /// Budget for frame payload per packet.
    fn frame_budget(&self) -> usize {
        self.cfg.mtu
            - 20 // packet header
            - aead::TAG_LEN
            - self.cfg.profile.per_packet_overhead
            - 40 // frame encoding headroom
    }

    /// Produce encoded packets ready to send on the current path.
    pub fn poll_output(&mut self, now: Time) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        if self.state == State::Closed && self.ctrl.is_empty() {
            return out;
        }
        let budget = self.frame_budget();
        let mut first = true;
        loop {
            let mut frames: Vec<Frame> = Vec::new();
            let mut used = 0usize;
            // 1. ACK: piggyback whenever other frames go out, send alone
            //    when 2+ packets are unacked or the delayed-ACK timer is due.
            let have_other = !self.ctrl.is_empty()
                || (self.can_send_app() && !self.scheduler.is_empty());
            let ack_due = self.ack_eliciting_unacked >= 2
                || self.ack_deadline.map_or(false, |d| now >= d)
                || have_other;
            if first && self.ack_eliciting_unacked > 0 && ack_due {
                if let Some(ack) = self.make_ack() {
                    let sz = ack.wire_size_hint();
                    used += sz;
                    self.ack_bytes_sent += sz as u64;
                    frames.push(ack);
                    self.ack_eliciting_unacked = 0;
                    self.ack_deadline = None;
                }
            }
            first = false;
            // 2. Control frames. Handshake-class frames (sent in plaintext)
            //    never share a packet with encrypted app frames; ACKs may
            //    ride with either class.
            let is_hs_class =
                |k: u64| matches!(k, frame::K_HANDSHAKE | frame::K_SYN | frame::K_SYN_ACK);
            while used < budget {
                let Some(f) = self.ctrl.front() else { break };
                let sz = f.wire_size_hint();
                if used + sz > budget && !frames.is_empty() {
                    break;
                }
                let have_hs = frames.iter().any(|q| is_hs_class(q.kind));
                let have_app = frames.iter().any(|q| q.kind != frame::K_ACK && !is_hs_class(q.kind));
                if (is_hs_class(f.kind) && have_app) || (!is_hs_class(f.kind) && have_hs) {
                    break; // class boundary: flush current packet first
                }
                let f = self.ctrl.pop_front().unwrap();
                if f.kind == frame::K_CONN_CLOSE {
                    self.state = State::Closed;
                }
                used += sz;
                frames.push(f);
            }
            // A handshake-class packet carries no stream data.
            if frames.iter().any(|f| is_hs_class(f.kind)) {
                let pkt_bytes = self.seal_frames(now, &frames, false);
                out.push(pkt_bytes);
                continue;
            }
            // 3. Stream data (only after establishment; congestion-window
            //    and pacer limited). The scheduler drains classes in
            //    priority order and round-robins within the winning class.
            let window = self.window();
            if self.can_send_app()
                && self.scheduler.current().is_some()
                && self.inflight + (used as u64) < window
                && (!self.cfg.pacing || self.pacer.try_send(now, window, self.rtt.srtt()))
            {
                while used + 64 < budget && self.inflight + (used as u64) < window {
                    let Some(sid) = self.scheduler.current() else { break };
                    let room = budget - used;
                    let take = self
                        .send_streams
                        .get_mut(&sid)
                        .and_then(|s| s.take_chunk(room.saturating_sub(48)));
                    match take {
                        Some((off, data, fin)) => {
                            used += data.len() + 48;
                            frames.push(Frame::stream_data(sid, off, data, fin));
                            // Rotate for fairness within the class.
                            self.scheduler.rotate();
                        }
                        None => self.scheduler.remove_current(),
                    }
                }
            }
            if frames.is_empty() {
                break;
            }
            let encrypt = self.tx_key.is_some()
                && frames
                    .iter()
                    .all(|f| !matches!(f.kind, frame::K_HANDSHAKE | frame::K_SYN | frame::K_SYN_ACK));
            let pkt_bytes = self.seal_frames(now, &frames, encrypt);
            out.push(pkt_bytes);
            if self.state == State::Closed {
                break;
            }
        }
        out
    }

    /// Build the datagram in one buffer: header, then frames encoded in
    /// place, then (optionally) the frame section encrypted where it sits
    /// with the header as associated data. No intermediate payload
    /// allocation or ciphertext copy (see DESIGN.md §Buffer ownership).
    fn seal_frames(&mut self, now: Time, frames: &[Frame], encrypt: bool) -> Vec<u8> {
        let num = self.next_pkt_num;
        self.next_pkt_num += 1;
        let hint: usize = frames.iter().map(|f| f.wire_size_hint()).sum();
        let mut out = Vec::with_capacity(27 + hint + aead::TAG_LEN);
        out.extend_from_slice(&self.remote_cid.to_le_bytes());
        out.extend_from_slice(&self.local_cid.to_le_bytes());
        crate::util::varint::put_uvarint(&mut out, num);
        out.push(if encrypt { crate::transport::packet::F_ENCRYPTED } else { 0 });
        let header_len = out.len();
        frame::encode_frames_into(&mut out, frames);
        if encrypt {
            // The wire header doubles as the AEAD associated data; it must
            // match Packet::header_bytes on the receive side.
            let mut nonce = [0u8; 12];
            nonce[4..].copy_from_slice(&num.to_be_bytes());
            let mut hdr = [0u8; 27]; // 16 cids + ≤10 varint + 1 flag
            hdr[..header_len].copy_from_slice(&out[..header_len]);
            aead::seal_in_place(
                self.tx_key.as_ref().unwrap(),
                &nonce,
                &hdr[..header_len],
                &mut out,
                header_len,
            );
        }
        let size = (out.len() - header_len) as u64 + 20;
        // Only data packets consume pacing budget (ACKs and control must
        // never be delayed — they are the peer's clock).
        if self.cfg.pacing && frames.iter().any(|f| f.kind == frame::K_STREAM_DATA) {
            self.pacer.on_sent(size);
        }
        let ack_eliciting = frames.iter().any(|f| f.is_ack_eliciting());
        let retrans: Vec<Frame> = frames
            .iter()
            .filter(|f| f.is_retransmittable())
            .cloned()
            .collect();
        if !retrans.is_empty() {
            self.sent.insert(
                num,
                SentPacket {
                    frames: retrans,
                    size,
                    sent_at: now,
                    ack_eliciting,
                },
            );
            self.inflight += size;
        }
        self.bytes_sent += size;
        self.last_send = now;
        out
    }

    /// Encode a one-off packet outside the normal flow (path probes).
    fn seal_packet(&mut self, frames: Vec<Frame>, encrypt: bool) -> Vec<u8> {
        let now = self.last_send;
        self.seal_frames(now, &frames, encrypt && self.tx_key.is_some())
    }

    /// Whether a sendable chunk is waiting (credit available, FIN pending);
    /// used to decide if the pacer's refill deadline matters.
    fn has_sendable_data(&self) -> bool {
        self.scheduler.active_ids().any(|sid| {
            self.send_streams
                .get(sid)
                .map_or(false, |s| s.can_send() || s.fin_pending())
        })
    }

    /// Earliest deadline at which [`Connection::on_timer`] must run.
    pub fn next_timeout(&self, now: Time) -> Option<Time> {
        if self.state == State::Closed {
            return None;
        }
        let mut t: Option<Time> = None;
        let mut consider = |x: Time| {
            t = Some(t.map_or(x, |v: Time| v.min(x)));
        };
        let rto = self.backed_off_rto();
        if let Some(l) = self.largest_acked {
            // Packets behind the delivery front: RACK tail-loss deadline
            // (same expression as detect_lost's tail arm).
            if let Some((_, sp)) = self.sent.range(..l).next() {
                consider(sp.sent_at + self.tail_delay());
            }
            // Packets with no delivered successor: the RTO last resort.
            if let Some((_, sp)) = self.sent.range(l..).next() {
                consider(sp.sent_at + rto);
            }
        } else if let Some((_, sp)) = self.sent.iter().next() {
            consider(sp.sent_at + rto);
        }
        // Pacer refill, when data is waiting on tokens (not on cwnd).
        if self.cfg.pacing
            && self.can_send_app()
            && self.inflight < self.window()
            && self.has_sendable_data()
        {
            consider(self.pacer.next_ready(now, self.window(), self.rtt.srtt()));
        }
        if let Some(d) = self.ack_deadline {
            consider(d);
        }
        if let Some(ka) = self.cfg.keepalive {
            if self.state == State::Established {
                consider(self.last_send + ka);
            }
        }
        consider(self.last_recv + self.cfg.idle_timeout);
        // Handshake stall guard.
        if self.state != State::Established {
            consider(self.created_at + self.cfg.idle_timeout / 2);
        }
        t
    }

    /// Timer tick: retransmissions, keepalive, idle teardown.
    pub fn on_timer(&mut self, now: Time) {
        if self.state == State::Closed {
            return;
        }
        // Idle timeout.
        if now.saturating_sub(self.last_recv) >= self.cfg.idle_timeout {
            self.state = State::Closed;
            self.closed_reason = Some("idle timeout".into());
            self.events.push_back(ConnEvent::Closed {
                error: "idle timeout".into(),
            });
            return;
        }
        // Handshake stall.
        if self.state != State::Established
            && now.saturating_sub(self.created_at) >= self.cfg.idle_timeout / 2
        {
            self.state = State::Closed;
            self.closed_reason = Some("handshake timeout".into());
            self.events.push_back(ConnEvent::Closed {
                error: "handshake timeout".into(),
            });
            return;
        }
        // RACK tail-loss: packets behind the delivery front whose time
        // threshold elapsed recover here without touching the RTO backoff.
        self.detect_lost(now);
        // RTO last resort, only for packets with no delivered successor
        // (the ack clock is gone). `sent` is ordered by packet number and
        // timestamps are non-decreasing, so expired packets form a prefix
        // of the candidate range — walk from the earliest deadline (the
        // same computation `next_timeout` uses) and stop at the first
        // unexpired packet instead of rescanning every sent packet.
        let rto = self.backed_off_rto();
        let from = self.largest_acked.unwrap_or(0);
        let mut expired = Vec::new();
        for (&k, sp) in self.sent.range(from..) {
            if now.saturating_sub(sp.sent_at) >= rto {
                expired.push(k);
            } else {
                break;
            }
        }
        if !expired.is_empty() {
            self.rto_backoff += 1;
            self.mark_lost(now, expired, true);
        }
        // Keepalive.
        if let Some(ka) = self.cfg.keepalive {
            if self.state == State::Established && now.saturating_sub(self.last_send) >= ka {
                self.ctrl.push_back(Frame::ping());
            }
        }
    }

    pub fn poll_event(&mut self) -> Option<ConnEvent> {
        self.events.pop_front()
    }

    /// Whether any output is pending (data, ctrl, acks).
    pub fn wants_send(&self) -> bool {
        !self.ctrl.is_empty()
            || self.ack_eliciting_unacked >= 2
            || (self.can_send_app() && self.has_sendable_data())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::SECOND;

    struct Pair {
        a: Connection,
        b: Connection,
        now: Time,
    }

    impl Pair {
        fn new(profile: TransportProfile) -> Pair {
            let mut rng = Rng::new(42);
            let cfg = ConnectionConfig {
                profile,
                ..ConnectionConfig::default()
            };
            let ka = Keypair::from_seed(1);
            let kb = Keypair::from_seed(2);
            let a = Connection::new(Role::Client, cfg.clone(), ka, 0, &mut rng);
            let b = Connection::new(Role::Server, cfg, kb, 0, &mut rng);
            Pair { a, b, now: 0 }
        }

        /// Shuttle packets until both sides go quiet. Returns round count.
        fn pump(&mut self) -> usize {
            let mut rounds = 0;
            loop {
                self.now += MILLI;
                let out_a = self.a.poll_output(self.now);
                let out_b = self.b.poll_output(self.now);
                if out_a.is_empty() && out_b.is_empty() {
                    break;
                }
                rounds += 1;
                for p in out_a {
                    let pkt = Packet::decode(&p).unwrap();
                    self.b.handle_packet(self.now, pkt).unwrap();
                }
                for p in out_b {
                    let pkt = Packet::decode(&p).unwrap();
                    self.a.handle_packet(self.now, pkt).unwrap();
                }
                assert!(rounds < 1000, "pump did not converge");
            }
            rounds
        }

        fn events(conn: &mut Connection) -> Vec<ConnEvent> {
            let mut v = Vec::new();
            while let Some(e) = conn.poll_event() {
                v.push(e);
            }
            v
        }
    }

    #[test]
    fn quic_like_establishes() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        assert!(p.a.is_established());
        assert!(p.b.is_established());
        assert_eq!(p.a.peer, Some(Keypair::from_seed(2).peer_id()));
        assert_eq!(p.b.peer, Some(Keypair::from_seed(1).peer_id()));
        let evs = Pair::events(&mut p.a);
        assert!(matches!(evs[0], ConnEvent::Established { .. }));
    }

    #[test]
    fn tcp_like_establishes_with_extra_rtt() {
        let mut pq = Pair::new(TransportProfile::QUIC_LIKE);
        let rq = pq.pump();
        let mut pt = Pair::new(TransportProfile::TCP_LIKE);
        let rt = pt.pump();
        assert!(pt.a.is_established() && pt.b.is_established());
        assert!(rt > rq, "TCP-like must need more round trips ({rt} vs {rq})");
    }

    #[test]
    fn stream_messages_flow_both_ways() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let sid = p.a.open_stream("/test/1");
        p.a.send_msg(sid, b"request").unwrap();
        p.pump();
        let evs = Pair::events(&mut p.b);
        let mut opened = None;
        let mut msg = None;
        for e in evs {
            match e {
                ConnEvent::StreamOpened { stream_id, proto } => opened = Some((stream_id, proto)),
                ConnEvent::Msg { stream_id, msg: m } => msg = Some((stream_id, m)),
                _ => {}
            }
        }
        let (osid, oproto) = opened.expect("stream opened");
        assert_eq!(osid, sid);
        assert_eq!(oproto, "/test/1");
        let (msid, mbody) = msg.unwrap();
        assert_eq!(msid, sid);
        assert_eq!(mbody, b"request");

        // Reply on the same stream.
        p.b.send_msg(sid, b"response").unwrap();
        p.pump();
        let evs = Pair::events(&mut p.a);
        assert!(evs
            .iter()
            .any(|e| matches!(e, ConnEvent::Msg { msg, .. } if msg == b"response")));
    }

    #[test]
    fn large_message_fragments() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let sid = p.a.open_stream("/big/1");
        let big: Vec<u8> = (0..300_000).map(|i| (i % 251) as u8).collect();
        p.a.send_msg(sid, &big).unwrap();
        p.pump();
        let evs = Pair::events(&mut p.b);
        let got: Vec<&Buf> = evs
            .iter()
            .filter_map(|e| match e {
                ConnEvent::Msg { msg, .. } => Some(msg),
                _ => None,
            })
            .collect();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], &big);
    }

    #[test]
    fn data_before_handshake_queues() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        // Open + send immediately, before any packet exchange.
        let sid = p.a.open_stream("/early/1");
        p.a.send_msg(sid, b"early-data").unwrap();
        p.pump();
        assert!(p.a.is_established());
        let evs = Pair::events(&mut p.b);
        assert!(evs
            .iter()
            .any(|e| matches!(e, ConnEvent::Msg { msg, .. } if msg == b"early-data")));
    }

    #[test]
    fn loss_recovered_by_rto() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let sid = p.a.open_stream("/lossy/1");
        p.a.send_msg(sid, b"will-be-lost").unwrap();
        // Drop A's first flight.
        let lost = p.a.poll_output(p.now + MILLI);
        assert!(!lost.is_empty());
        drop(lost);
        // Fire RTO.
        let deadline = p.a.next_timeout(p.now).unwrap();
        p.a.on_timer(deadline);
        p.now = deadline;
        p.pump();
        let evs = Pair::events(&mut p.b);
        assert!(
            evs.iter()
                .any(|e| matches!(e, ConnEvent::Msg { msg, .. } if msg == b"will-be-lost")),
            "retransmission must deliver the message"
        );
        assert!(p.a.packets_retransmitted > 0);
    }

    #[test]
    fn fin_closes_stream() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let sid = p.a.open_stream("/fin/1");
        p.a.send_msg(sid, b"last").unwrap();
        p.a.finish_stream(sid);
        p.pump();
        let evs = Pair::events(&mut p.b);
        assert!(evs
            .iter()
            .any(|e| matches!(e, ConnEvent::StreamFinished { stream_id } if *stream_id == sid)));
        // Sending after finish fails.
        assert!(p.a.send_msg(sid, b"more").is_err());
    }

    #[test]
    fn reset_surfaces_remotely() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let sid = p.a.open_stream("/rst/1");
        p.a.send_msg(sid, b"x").unwrap();
        p.pump();
        Pair::events(&mut p.b);
        p.a.reset_stream(sid, "cancelled");
        p.pump();
        let evs = Pair::events(&mut p.b);
        assert!(evs.iter().any(
            |e| matches!(e, ConnEvent::StreamReset { stream_id, error } if *stream_id == sid && error == "cancelled")
        ));
    }

    #[test]
    fn close_propagates() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        p.a.close("done");
        p.pump();
        assert!(p.a.is_closed());
        assert!(p.b.is_closed());
        let evs = Pair::events(&mut p.b);
        assert!(evs
            .iter()
            .any(|e| matches!(e, ConnEvent::Closed { error } if error == "done")));
    }

    #[test]
    fn idle_timeout_fires() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let t = p.now + 31 * SECOND;
        p.a.on_timer(t);
        assert!(p.a.is_closed());
        let evs = Pair::events(&mut p.a);
        assert!(evs
            .iter()
            .any(|e| matches!(e, ConnEvent::Closed { error } if error.contains("idle"))));
    }

    #[test]
    fn path_challenge_response() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let probe = p.a.make_path_challenge(0xBEEF);
        let pkt = Packet::decode(&probe).unwrap();
        let info = p.b.handle_packet(p.now, pkt).unwrap();
        assert!(info.accepted);
        assert_eq!(info.path_challenges, vec![0xBEEF]);
        // The swarm answers on the arrival path:
        let resp = p.b.make_path_response(0xBEEF);
        let info = p
            .a
            .handle_packet(p.now, Packet::decode(&resp).unwrap())
            .unwrap();
        assert_eq!(info.path_responses, vec![0xBEEF]);
        let evs = Pair::events(&mut p.a);
        assert!(evs
            .iter()
            .any(|e| matches!(e, ConnEvent::PathValidated { token } if *token == 0xBEEF)));
    }

    #[test]
    fn tampered_packet_dropped_silently() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let sid = p.a.open_stream("/t/1");
        p.a.send_msg(sid, b"payload").unwrap();
        let mut pkts = p.a.poll_output(p.now + MILLI);
        for pkt in &mut pkts {
            let n = pkt.len();
            pkt[n - 1] ^= 0xFF; // corrupt ciphertext
        }
        for pb in pkts {
            let pkt = Packet::decode(&pb).unwrap();
            p.b.handle_packet(p.now, pkt).unwrap();
        }
        let evs = Pair::events(&mut p.b);
        assert!(
            !evs.iter().any(|e| matches!(e, ConnEvent::Msg { .. })),
            "corrupted packets must not deliver data"
        );
    }

    #[test]
    fn many_concurrent_streams_interleave_fairly() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let mut sids = Vec::new();
        for i in 0..20 {
            let sid = p.a.open_stream("/multi/1");
            p.a.send_msg(sid, format!("stream-{i}").as_bytes()).unwrap();
            sids.push(sid);
        }
        p.pump();
        let evs = Pair::events(&mut p.b);
        let msgs: Vec<_> = evs
            .iter()
            .filter(|e| matches!(e, ConnEvent::Msg { .. }))
            .collect();
        assert_eq!(msgs.len(), 20);
    }

    #[test]
    fn rtt_estimated_from_acks() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let sid = p.a.open_stream("/rtt/1");
        for _ in 0..5 {
            p.a.send_msg(sid, b"ping-data").unwrap();
            p.pump();
        }
        assert!(p.a.rtt.has_sample());
    }

    /// LossyWan-grade loss, compressed in time: dropping every other
    /// a→b packet leaves permanent holes in b's packet-number space
    /// (retransmits take fresh numbers), so the received-range list
    /// fragments without bound. The ACK builder must cap the frame at
    /// 32 ranges — well inside one MTU — and count the truncations.
    #[test]
    fn ack_ranges_bounded_under_heavy_loss() {
        let mut p = Pair::new(TransportProfile::QUIC_LIKE);
        p.pump();
        let sid = p.a.open_stream("/lossy-wan/1");
        let mut delivered = 0u32;
        for round in 0u64..600 {
            p.now += MILLI;
            let _ = p.a.send_msg(sid, b"chunk-of-loss-test-payload");
            if let Some(t) = p.a.next_timeout(p.now) {
                if t <= p.now {
                    p.a.on_timer(p.now);
                }
            }
            for (i, pb) in p.a.poll_output(p.now).into_iter().enumerate() {
                if (round + i as u64) % 2 == 0 {
                    let pkt = Packet::decode(&pb).unwrap();
                    p.b.handle_packet(p.now, pkt).unwrap();
                    delivered += 1;
                }
            }
            for pb in p.b.poll_output(p.now) {
                let pkt = Packet::decode(&pb).unwrap();
                p.a.handle_packet(p.now, pkt).unwrap();
            }
        }
        assert!(delivered > 64, "not enough traffic survived: {delivered}");
        assert!(
            p.b.recv_ranges.len() > 32,
            "loss pattern too tame to fragment ({} ranges)",
            p.b.recv_ranges.len()
        );
        let ack = p.b.make_ack().expect("pending ranges");
        // 32 ranges → 32 runs + 31 gaps.
        assert!(
            ack.ack_ranges.len() <= 63,
            "ACK carries {} values",
            ack.ack_ranges.len()
        );
        assert!(ack.wire_size_hint() < 1200, "ACK frame must fit one MTU");
        let s = p.b.stats();
        assert!(s.ack_truncations > 0, "truncations must be counted");
        assert!(s.ack_bytes_sent > 0, "ACK bytes must be accounted");
        // The peer keeps making forward progress on truncated ACKs.
        assert!(p.a.largest_acked.is_some());
    }
}
