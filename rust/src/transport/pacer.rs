//! Token-bucket pacing of `poll_output`.
//!
//! Without pacing the connection machine flushes a full congestion window
//! in one instant, which slams the simulator's bounded link queues
//! (drop-tail bursts) and defeats loss detection (hundreds of packets share
//! one send timestamp). The pacer spreads data packets over the round trip
//! at `5/4 · cwnd / srtt` — the classic QUIC pacing gain, slightly above
//! the ack clock so the window can grow.
//!
//! Only packets carrying STREAM_DATA are paced; ACKs, handshake and other
//! control frames bypass the bucket entirely (delaying the ack clock would
//! throttle the peer).
//!
//! The bucket runs on a signed token count: a packet may depart whenever
//! the balance is positive and then debits its full size, so one oversized
//! packet borrows ahead instead of deadlocking, and the debt delays the
//! next departure. [`Pacer::next_ready`] exposes the replenish deadline so
//! the connection can arm a timer instead of busy-polling.

use crate::netsim::{Time, MICRO, SECOND};

/// Pacing gain: send at 5/4 of the ack-clocked rate.
const GAIN_NUM: u128 = 5;
const GAIN_DEN: u128 = 4;

/// Burst allowance: at least this many segments may leave back-to-back.
const BURST_SEGMENTS: u64 = 10;

/// Guard for rate arithmetic on sub-RTT paths (loopback srtt is ~30 µs).
const MIN_SRTT: Time = 10 * MICRO;

#[derive(Debug)]
pub struct Pacer {
    /// Token balance in bytes (may go negative: a departing packet debits
    /// its full size after the positive-balance check).
    tokens: i64,
    last_refill: Time,
    /// Packets granted immediately.
    pub sends: u64,
    /// Send opportunities delayed until the bucket refilled.
    pub throttles: u64,
}

impl Pacer {
    pub fn new(now: Time, cwnd: u64) -> Pacer {
        Pacer {
            tokens: Self::burst(cwnd) as i64,
            last_refill: now,
            sends: 0,
            throttles: 0,
        }
    }

    /// Bytes per second for the current window and RTT estimate.
    fn rate(cwnd: u64, srtt: Time) -> u64 {
        let srtt = srtt.max(MIN_SRTT) as u128;
        (cwnd as u128 * SECOND as u128 * GAIN_NUM / (srtt * GAIN_DEN)) as u64
    }

    /// Bucket capacity: a fraction of the window, floored at a fixed burst.
    fn burst(cwnd: u64) -> u64 {
        (BURST_SEGMENTS * super::cc::MSS).max(cwnd / 8)
    }

    fn refill(&mut self, now: Time, cwnd: u64, srtt: Time) {
        let dt = now.saturating_sub(self.last_refill);
        if dt == 0 {
            return;
        }
        let add = (Self::rate(cwnd, srtt) as u128 * dt as u128 / SECOND as u128) as i64;
        if add == 0 {
            // Keep accruing from `last_refill`: advancing the clock here
            // would floor away sub-token progress on every call and could
            // stall the bucket under frequent polling.
            return;
        }
        self.tokens = (self.tokens + add).min(Self::burst(cwnd) as i64);
        self.last_refill = now;
    }

    /// Whether a data packet may depart now. Call [`Pacer::on_sent`] with
    /// the actual packet size afterwards.
    pub fn try_send(&mut self, now: Time, cwnd: u64, srtt: Time) -> bool {
        self.refill(now, cwnd, srtt);
        if self.tokens > 0 {
            self.sends += 1;
            true
        } else {
            self.throttles += 1;
            false
        }
    }

    /// Debit a departed packet.
    pub fn on_sent(&mut self, bytes: u64) {
        self.tokens -= bytes as i64;
    }

    /// Earliest instant the bucket balance turns positive again (equals
    /// `now` when sending is already allowed).
    pub fn next_ready(&self, now: Time, cwnd: u64, srtt: Time) -> Time {
        let dt = now.saturating_sub(self.last_refill);
        let rate = Self::rate(cwnd, srtt).max(1);
        let accrued = (rate as u128 * dt as u128 / SECOND as u128) as i64;
        let balance = (self.tokens + accrued).min(Self::burst(cwnd) as i64);
        if balance > 0 {
            return now;
        }
        let deficit = (1 - balance) as u128;
        now + ((deficit * SECOND as u128 + rate as u128 - 1) / rate as u128) as Time
    }

    /// Share of send opportunities that had to wait for tokens (0.0 = the
    /// pacer never bit, 1.0 = fully pacing-limited).
    pub fn utilization(&self) -> f64 {
        let total = self.sends + self.throttles;
        if total == 0 {
            return 0.0;
        }
        self.throttles as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::MILLI;
    use crate::transport::cc::MSS;

    const CWND: u64 = 64 * MSS;
    const SRTT: Time = 10 * MILLI;

    #[test]
    fn burst_then_throttle() {
        let mut p = Pacer::new(0, CWND);
        let mut granted = 0;
        while p.try_send(0, CWND, SRTT) {
            p.on_sent(MSS);
            granted += 1;
            assert!(granted < 1000, "pacer never throttled");
        }
        // The initial burst is bounded by the bucket, not the window.
        assert!(granted >= BURST_SEGMENTS && granted <= 2 * BURST_SEGMENTS, "granted={granted}");
        assert!(p.throttles > 0);
    }

    #[test]
    fn refills_at_cwnd_per_rtt_rate() {
        let mut p = Pacer::new(0, CWND);
        while p.try_send(0, CWND, SRTT) {
            p.on_sent(MSS);
        }
        // After one full srtt the bucket admits ~cwnd·5/4 more bytes, but
        // the burst cap keeps the instantaneous balance small.
        let mut sent = 0u64;
        let mut now = 0;
        for _ in 0..20 {
            now += SRTT / 20;
            while p.try_send(now, CWND, SRTT) {
                p.on_sent(MSS);
                sent += MSS;
            }
        }
        let expect = CWND * 5 / 4;
        assert!(
            sent > expect * 8 / 10 && sent < expect * 12 / 10,
            "one-RTT budget: sent {sent} expect ~{expect}"
        );
    }

    #[test]
    fn next_ready_matches_refill() {
        let mut p = Pacer::new(0, CWND);
        while p.try_send(0, CWND, SRTT) {
            p.on_sent(MSS);
        }
        let ready = p.next_ready(0, CWND, SRTT);
        assert!(ready > 0, "throttled bucket must report a future deadline");
        assert!(!p.try_send(ready - 1, CWND, SRTT));
        assert!(p.try_send(ready, CWND, SRTT), "deadline must admit a send");
    }

    #[test]
    fn utilization_tracks_throttling() {
        let mut p = Pacer::new(0, CWND);
        assert_eq!(p.utilization(), 0.0);
        while p.try_send(0, CWND, SRTT) {
            p.on_sent(MSS);
        }
        assert!(p.utilization() > 0.0 && p.utilization() < 1.0);
    }
}
