//! Pluggable congestion control for the connection machine.
//!
//! The connection used to cap in-flight bytes with a fixed budget
//! (`max_inflight`, a congestion-window stand-in). This module replaces it
//! with a real [`CongestionController`]: the controller owns the window,
//! grows it on acknowledgments and shrinks it on loss rounds, and the
//! connection clamps the result with `max_inflight` (which survives as a
//! hard upper bound — relay tunnels still pin it low).
//!
//! Two real controllers are provided — **NewReno** (RFC 6582 shape: slow
//! start + AIMD) and **CUBIC** (RFC 8312: cubic window recovery toward the
//! pre-loss plateau, beta 0.7, TCP-friendly floor) — plus a **fixed**
//! window that reproduces the seed's behaviour for baselines and tunnels.
//!
//! Controllers respond to a *loss round*, not every lost packet: a loss
//! whose packet was sent before the current recovery episode started is
//! part of the same round and must not shrink the window again (standard
//! once-per-RTT reduction). Both implementations enforce this with a
//! `recovery_start` timestamp compared against the lost packet's send time.

use super::rtt::RttEstimator;
use crate::netsim::Time;

/// Nominal segment size used for window arithmetic (datagram payload minus
/// packet/AEAD/frame overhead; the simulator MTU is 1400).
pub const MSS: u64 = 1200;

/// Initial congestion window (generous: the paper's testbed is datacenter
/// links; lossy paths shrink it within one round trip).
pub const INITIAL_CWND: u64 = 32 * MSS;

/// Floor: never close the window below two segments.
pub const MIN_CWND: u64 = 2 * MSS;

/// Congestion-control algorithm selector (per role via `NodeConfig`, per
/// connection via `ConnectionConfig`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CcAlgorithm {
    /// Seed behaviour: a constant window (`max_inflight` clamps it).
    Fixed,
    /// Slow start + AIMD with once-per-round halving.
    NewReno,
    /// RFC 8312 cubic growth with fast convergence.
    Cubic,
}

impl CcAlgorithm {
    pub fn name(&self) -> &'static str {
        match self {
            CcAlgorithm::Fixed => "fixed",
            CcAlgorithm::NewReno => "newreno",
            CcAlgorithm::Cubic => "cubic",
        }
    }

    /// Parse a config-file value ("fixed" | "newreno" | "cubic").
    pub fn parse(s: &str) -> Option<CcAlgorithm> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" => Some(CcAlgorithm::Fixed),
            "newreno" | "reno" => Some(CcAlgorithm::NewReno),
            "cubic" => Some(CcAlgorithm::Cubic),
            _ => None,
        }
    }

    /// Build a controller whose window never grows past `max_cwnd` (the
    /// connection's `max_inflight` ceiling). Without the cap the internal
    /// window could inflate ~2× past the clamp on a clean path, making
    /// the first loss round's multiplicative decrease a no-op and (for
    /// CUBIC) recording a plateau the path never carried.
    pub fn build(&self, max_cwnd: u64) -> Box<dyn CongestionController> {
        match self {
            CcAlgorithm::Fixed => Box::new(FixedWindow::new(u64::MAX)),
            CcAlgorithm::NewReno => {
                let mut c = NewReno::new();
                c.max_cwnd = max_cwnd;
                Box::new(c)
            }
            CcAlgorithm::Cubic => {
                let mut c = Cubic::new();
                c.max_cwnd = max_cwnd;
                Box::new(c)
            }
        }
    }
}

/// The congestion-controller contract (see DESIGN.md §Congestion control).
///
/// * `on_ack` is called once per newly acknowledged packet, with the
///   in-flight byte count *before* this ACK was processed so controllers
///   can skip growth while application-limited.
/// * `on_loss` is called once per lost packet; `sent_at` lets the
///   controller collapse a burst of losses into one round. `persistent`
///   marks RTO-driven loss (no ack clock left): collapse to the minimum
///   window instead of the multiplicative decrease.
/// * `cwnd` returns the current window in bytes; the connection clamps it
///   to `[MIN_CWND, max_inflight]`.
pub trait CongestionController {
    fn on_ack(
        &mut self,
        now: Time,
        sent_at: Time,
        bytes: u64,
        prior_inflight: u64,
        rtt: &RttEstimator,
    );
    fn on_loss(&mut self, now: Time, sent_at: Time, persistent: bool, rtt: &RttEstimator);
    fn cwnd(&self) -> u64;
    fn name(&self) -> &'static str;
}

/// Whether an ACK should grow the window: growth is earned only while the
/// sender is actually window-limited, otherwise idle periods inflate cwnd
/// far past what the path ever carried.
fn cwnd_limited(prior_inflight: u64, bytes: u64, cwnd: u64) -> bool {
    prior_inflight + bytes >= cwnd / 2
}

// ---------------------------------------------------------------------
// Fixed window (seed baseline)
// ---------------------------------------------------------------------

/// Constant window: the seed's `max_inflight` budget as a controller.
#[derive(Debug)]
pub struct FixedWindow {
    window: u64,
}

impl FixedWindow {
    pub fn new(window: u64) -> FixedWindow {
        FixedWindow { window }
    }
}

impl CongestionController for FixedWindow {
    fn on_ack(&mut self, _: Time, _: Time, _: u64, _: u64, _: &RttEstimator) {}
    fn on_loss(&mut self, _: Time, _: Time, _: bool, _: &RttEstimator) {}

    fn cwnd(&self) -> u64 {
        self.window
    }

    fn name(&self) -> &'static str {
        "fixed"
    }
}

// ---------------------------------------------------------------------
// NewReno
// ---------------------------------------------------------------------

/// Slow start to `ssthresh`, then one MSS per window of acknowledged bytes;
/// halve once per loss round.
#[derive(Debug)]
pub struct NewReno {
    cwnd: u64,
    ssthresh: u64,
    /// Growth ceiling (the connection's clamp; see `CcAlgorithm::build`).
    max_cwnd: u64,
    /// Packets sent at or before this instant belong to an already-handled
    /// loss round (and their ACKs must not grow the post-reduction window).
    recovery_start: Time,
    /// Acked-byte accumulator for congestion avoidance.
    acked: u64,
}

impl NewReno {
    pub fn new() -> NewReno {
        NewReno {
            cwnd: INITIAL_CWND,
            ssthresh: u64::MAX,
            max_cwnd: u64::MAX,
            recovery_start: 0,
            acked: 0,
        }
    }
}

impl Default for NewReno {
    fn default() -> Self {
        Self::new()
    }
}

impl CongestionController for NewReno {
    fn on_ack(
        &mut self,
        _now: Time,
        sent_at: Time,
        bytes: u64,
        prior_inflight: u64,
        _rtt: &RttEstimator,
    ) {
        if sent_at <= self.recovery_start || !cwnd_limited(prior_inflight, bytes, self.cwnd) {
            return;
        }
        if self.cwnd < self.ssthresh {
            self.cwnd += bytes; // slow start: one MSS per MSS acked
        } else {
            self.acked += bytes;
            if self.acked >= self.cwnd {
                self.acked -= self.cwnd;
                self.cwnd += MSS;
            }
        }
        self.cwnd = self.cwnd.min(self.max_cwnd);
    }

    fn on_loss(&mut self, now: Time, sent_at: Time, persistent: bool, _rtt: &RttEstimator) {
        if sent_at <= self.recovery_start && !persistent {
            return; // same loss round
        }
        self.recovery_start = now;
        self.acked = 0;
        if persistent {
            self.ssthresh = (self.cwnd / 2).max(MIN_CWND);
            self.cwnd = MIN_CWND;
        } else {
            self.cwnd = (self.cwnd / 2).max(MIN_CWND);
            self.ssthresh = self.cwnd;
        }
    }

    fn cwnd(&self) -> u64 {
        self.cwnd
    }

    fn name(&self) -> &'static str {
        "newreno"
    }
}

// ---------------------------------------------------------------------
// CUBIC (RFC 8312)
// ---------------------------------------------------------------------

/// Cube scaling constant (windows in MSS units, time in seconds).
const CUBIC_C: f64 = 0.4;
/// Multiplicative decrease factor.
const CUBIC_BETA: f64 = 0.7;

/// Window recovers along `W(t) = C·(t-K)³ + W_max`: concave approach to
/// the pre-loss plateau, then convex probing beyond it — far faster back
/// to a high-BDP operating point than NewReno's one-MSS-per-RTT crawl.
#[derive(Debug)]
pub struct Cubic {
    cwnd: u64,
    ssthresh: u64,
    /// Growth ceiling (the connection's clamp; see `CcAlgorithm::build`).
    max_cwnd: u64,
    recovery_start: Time,
    /// Pre-loss plateau, in MSS units.
    w_max: f64,
    /// Time (s) for `W(t)` to return to `w_max`.
    k: f64,
    /// Start of the current growth epoch (None until the first CA ack
    /// after a reduction).
    epoch_start: Option<Time>,
    /// Reno-friendly window estimate (RFC 8312 §4.2), in MSS units.
    w_est: f64,
}

impl Cubic {
    pub fn new() -> Cubic {
        Cubic {
            cwnd: INITIAL_CWND,
            ssthresh: u64::MAX,
            max_cwnd: u64::MAX,
            recovery_start: 0,
            w_max: INITIAL_CWND as f64 / MSS as f64,
            k: 0.0,
            epoch_start: None,
            w_est: 0.0,
        }
    }
}

impl Default for Cubic {
    fn default() -> Self {
        Self::new()
    }
}

impl CongestionController for Cubic {
    fn on_ack(
        &mut self,
        now: Time,
        sent_at: Time,
        bytes: u64,
        prior_inflight: u64,
        rtt: &RttEstimator,
    ) {
        if sent_at <= self.recovery_start || !cwnd_limited(prior_inflight, bytes, self.cwnd) {
            return;
        }
        if self.cwnd < self.ssthresh {
            self.cwnd = (self.cwnd + bytes).min(self.max_cwnd);
            return;
        }
        let mss = MSS as f64;
        let cw = self.cwnd as f64 / mss;
        if self.epoch_start.is_none() {
            self.epoch_start = Some(now);
            let wmax = self.w_max.max(cw);
            self.k = ((wmax - cw) / CUBIC_C).cbrt();
            self.w_est = cw;
        }
        let t = now.saturating_sub(self.epoch_start.unwrap()) as f64 / 1e9;
        let rtt_s = (rtt.srtt() as f64 / 1e9).max(1e-6);
        // Target the cubic curve one RTT ahead.
        let w_cubic = CUBIC_C * (t + rtt_s - self.k).powi(3) + self.w_max;
        // TCP-friendly floor: what AIMD with the same beta would reach.
        self.w_est += 3.0 * (1.0 - CUBIC_BETA) / (1.0 + CUBIC_BETA) * bytes as f64 / (cw * mss);
        let target = w_cubic.max(self.w_est);
        if target > cw {
            // Standard per-ack increment: (target - cwnd)/cwnd segments
            // per segment acknowledged.
            let inc = (target - cw) / cw * bytes as f64;
            self.cwnd = (self.cwnd + inc as u64).min(self.max_cwnd);
        }
    }

    fn on_loss(&mut self, now: Time, sent_at: Time, persistent: bool, _rtt: &RttEstimator) {
        if sent_at <= self.recovery_start && !persistent {
            return;
        }
        self.recovery_start = now;
        self.epoch_start = None;
        let mss = MSS as f64;
        let cw = self.cwnd as f64 / mss;
        // Fast convergence: a shrinking flow releases bandwidth early.
        self.w_max = if cw < self.w_max {
            cw * (1.0 + CUBIC_BETA) / 2.0
        } else {
            cw
        };
        if persistent {
            self.ssthresh = ((cw * CUBIC_BETA * mss) as u64).max(MIN_CWND);
            self.cwnd = MIN_CWND;
        } else {
            self.cwnd = ((cw * CUBIC_BETA * mss) as u64).max(MIN_CWND);
            self.ssthresh = self.cwnd;
        }
    }

    fn cwnd(&self) -> u64 {
        self.cwnd
    }

    fn name(&self) -> &'static str {
        "cubic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::MILLI;

    fn rtt_at(ms: u64) -> RttEstimator {
        let mut r = RttEstimator::new();
        for _ in 0..20 {
            r.on_sample(ms * MILLI);
        }
        r
    }

    #[test]
    fn parse_and_names() {
        assert_eq!(CcAlgorithm::parse("cubic"), Some(CcAlgorithm::Cubic));
        assert_eq!(CcAlgorithm::parse("NewReno"), Some(CcAlgorithm::NewReno));
        assert_eq!(CcAlgorithm::parse("fixed"), Some(CcAlgorithm::Fixed));
        assert_eq!(CcAlgorithm::parse("bbr"), None);
        assert_eq!(CcAlgorithm::Cubic.build(1 << 20).name(), "cubic");
        assert_eq!(CcAlgorithm::NewReno.build(1 << 20).name(), "newreno");
    }

    #[test]
    fn growth_respects_ceiling() {
        let rtt = rtt_at(10);
        let cap = 4 * INITIAL_CWND;
        let mut cc = CcAlgorithm::Cubic.build(cap);
        for i in 1..64 {
            let w = cc.cwnd();
            cc.on_ack(i * MILLI, i * MILLI, w, w, &rtt);
        }
        assert_eq!(cc.cwnd(), cap, "slow start must stop at the ceiling");
        // The first loss after a capped plateau still shrinks the window.
        cc.on_loss(100 * MILLI, 99 * MILLI, false, &rtt);
        assert!(cc.cwnd() < cap, "loss at the ceiling must reduce: {}", cc.cwnd());
    }

    #[test]
    fn fixed_window_is_inert() {
        let mut f = FixedWindow::new(12345);
        let rtt = rtt_at(10);
        f.on_ack(0, 0, 1000, 12345, &rtt);
        f.on_loss(MILLI, 0, false, &rtt);
        assert_eq!(f.cwnd(), 12345);
    }

    #[test]
    fn newreno_slow_start_doubles_then_linear() {
        let mut cc = NewReno::new();
        let rtt = rtt_at(10);
        let w0 = cc.cwnd();
        // One window of acks in slow start doubles the window.
        cc.on_ack(MILLI, MILLI, w0, w0, &rtt);
        assert_eq!(cc.cwnd(), 2 * w0);
        // Leave slow start, then one window of acks adds ~1 MSS.
        cc.on_loss(2 * MILLI, 2 * MILLI, false, &rtt);
        let w1 = cc.cwnd();
        cc.on_ack(3 * MILLI, 3 * MILLI, w1, w1, &rtt);
        assert!(cc.cwnd() >= w1 + MSS && cc.cwnd() <= w1 + 2 * MSS, "cwnd={}", cc.cwnd());
    }

    #[test]
    fn newreno_halves_once_per_round() {
        let mut cc = NewReno::new();
        let rtt = rtt_at(10);
        let w0 = cc.cwnd();
        // Three losses from the same flight (all sent at t=5ms).
        cc.on_loss(10 * MILLI, 5 * MILLI, false, &rtt);
        cc.on_loss(10 * MILLI, 5 * MILLI, false, &rtt);
        cc.on_loss(11 * MILLI, 5 * MILLI, false, &rtt);
        assert_eq!(cc.cwnd(), w0 / 2, "one reduction per loss round");
        // A loss from a packet sent after the reduction opens a new round.
        cc.on_loss(30 * MILLI, 20 * MILLI, false, &rtt);
        assert_eq!(cc.cwnd(), w0 / 4);
    }

    #[test]
    fn persistent_loss_collapses_to_min() {
        let mut cc = NewReno::new();
        let rtt = rtt_at(10);
        cc.on_loss(MILLI, MILLI, true, &rtt);
        assert_eq!(cc.cwnd(), MIN_CWND);
        let mut cu = Cubic::new();
        cu.on_loss(MILLI, MILLI, true, &rtt);
        assert_eq!(cu.cwnd(), MIN_CWND);
    }

    #[test]
    fn app_limited_acks_do_not_grow() {
        let mut cc = NewReno::new();
        let rtt = rtt_at(10);
        let w0 = cc.cwnd();
        // Tiny inflight: acks must not inflate the window.
        cc.on_ack(MILLI, MILLI, MSS, MSS, &rtt);
        assert_eq!(cc.cwnd(), w0);
    }

    #[test]
    fn cubic_reduces_by_beta_and_recovers_toward_wmax() {
        let rtt = rtt_at(50);
        let mut cc = Cubic::new();
        // Grow to a plateau via slow start.
        for i in 1..8 {
            let w = cc.cwnd();
            cc.on_ack(i * 10 * MILLI, i * 10 * MILLI, w, w, &rtt);
        }
        let plateau = cc.cwnd();
        cc.on_loss(100 * MILLI, 99 * MILLI, false, &rtt);
        let floor = cc.cwnd();
        assert!(
            (floor as f64) < 0.75 * plateau as f64 && (floor as f64) > 0.6 * plateau as f64,
            "beta reduction: {floor} vs plateau {plateau}"
        );
        // Ack steadily for several virtual seconds: the window climbs back
        // toward the pre-loss plateau along the cubic curve.
        let mut now = 200 * MILLI;
        for _ in 0..3000 {
            let w = cc.cwnd();
            cc.on_ack(now, now, 8 * MSS, w, &rtt);
            now += 2 * MILLI;
        }
        assert!(
            cc.cwnd() > plateau * 85 / 100,
            "cubic must recover toward w_max: {} vs {plateau}",
            cc.cwnd()
        );
    }

    #[test]
    fn cubic_recovers_faster_than_newreno_at_high_bdp() {
        let rtt = rtt_at(75);
        let mut cu = Cubic::new();
        let mut nr = NewReno::new();
        // Both at a 4 MB plateau, both lose.
        let plateau = 4 << 20;
        while cu.cwnd() < plateau {
            let w = cu.cwnd();
            cu.on_ack(MILLI, MILLI, w, w, &rtt);
        }
        while nr.cwnd() < plateau {
            let w = nr.cwnd();
            nr.on_ack(MILLI, MILLI, w, w, &rtt);
        }
        cu.on_loss(10 * MILLI, 9 * MILLI, false, &rtt);
        nr.on_loss(10 * MILLI, 9 * MILLI, false, &rtt);
        // One simulated second of full-window ack clocking.
        let mut now = 20 * MILLI;
        for _ in 0..1000 {
            let (wc, wn) = (cu.cwnd(), nr.cwnd());
            cu.on_ack(now, now, MSS * 8, wc, &rtt);
            nr.on_ack(now, now, MSS * 8, wn, &rtt);
            now += MILLI;
        }
        assert!(
            cu.cwnd() > nr.cwnd(),
            "cubic {} must out-recover newreno {}",
            cu.cwnd(),
            nr.cwnd()
        );
    }
}
