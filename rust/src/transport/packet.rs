//! Packet header: plaintext connection routing + packet number, with the
//! frame section optionally AEAD-sealed (header as associated data).
//!
//! ```text
//! [dst_cid: u64 LE][src_cid: u64 LE][pkt_num: varint][flags: u8][payload]
//! ```
//!
//! `dst_cid == 0` marks the very first packet of a connection (the server
//! has not yet assigned its local id). Demultiplexing is by `dst_cid`, so a
//! connection survives source-address changes — this is what lets DCUtR
//! migrate a relayed connection to a punched direct path.
//!
//! The payload is a [`Buf`]: [`Packet::decode_buf`] slices the incoming
//! datagram instead of copying it, and the send side builds header +
//! payload in one buffer (see `Connection::seal_frames`).

use crate::util::buf::Buf;
use anyhow::{bail, Result};

/// Header flags.
pub const F_ENCRYPTED: u8 = 0x01;

#[derive(Clone, Debug, PartialEq)]
pub struct Packet {
    /// Receiver's connection id (0 = initial).
    pub dst_cid: u64,
    /// Sender's connection id (so the receiver learns where to reply).
    pub src_cid: u64,
    pub pkt_num: u64,
    pub encrypted: bool,
    pub payload: Buf,
}

impl Packet {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(18 + self.payload.len());
        out.extend_from_slice(&self.dst_cid.to_le_bytes());
        out.extend_from_slice(&self.src_cid.to_le_bytes());
        crate::util::varint::put_uvarint(&mut out, self.pkt_num);
        out.push(if self.encrypted { F_ENCRYPTED } else { 0 });
        out.extend_from_slice(&self.payload);
        out
    }

    /// Decode, keeping the payload as a zero-copy slice of `buf`.
    pub fn decode_buf(buf: &Buf) -> Result<Packet> {
        let b = buf.as_slice();
        if b.len() < 18 {
            bail!("packet too short: {} bytes", b.len());
        }
        let dst_cid = u64::from_le_bytes(b[0..8].try_into()?);
        let src_cid = u64::from_le_bytes(b[8..16].try_into()?);
        let (pkt_num, n) = crate::util::varint::get_uvarint(&b[16..])?;
        let fpos = 16 + n;
        let Some(&flags) = b.get(fpos) else {
            bail!("packet missing flags byte");
        };
        Ok(Packet {
            dst_cid,
            src_cid,
            pkt_num,
            encrypted: flags & F_ENCRYPTED != 0,
            payload: buf.slice(fpos + 1..),
        })
    }

    /// Decode from a plain slice (copies the payload; prefer
    /// [`Packet::decode_buf`] on the datagram path).
    pub fn decode(buf: &[u8]) -> Result<Packet> {
        Self::decode_buf(&Buf::copy_from_slice(buf))
    }

    /// The associated data for AEAD: everything before the payload.
    pub fn header_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(18);
        out.extend_from_slice(&self.dst_cid.to_le_bytes());
        out.extend_from_slice(&self.src_cid.to_le_bytes());
        crate::util::varint::put_uvarint(&mut out, self.pkt_num);
        out.push(if self.encrypted { F_ENCRYPTED } else { 0 });
        out
    }

    /// AEAD nonce derived from the packet number.
    pub fn nonce(&self) -> [u8; 12] {
        let mut n = [0u8; 12];
        n[4..].copy_from_slice(&self.pkt_num.to_be_bytes());
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let p = Packet {
            dst_cid: 0xAABBCCDD_11223344,
            src_cid: 7,
            pkt_num: 123_456,
            encrypted: true,
            payload: vec![1, 2, 3].into(),
        };
        let enc = p.encode();
        assert_eq!(Packet::decode(&enc).unwrap(), p);
    }

    #[test]
    fn decode_buf_payload_is_zero_copy() {
        let p = Packet {
            dst_cid: 1,
            src_cid: 2,
            pkt_num: 3,
            encrypted: false,
            payload: vec![9u8; 100].into(),
        };
        let datagram = Buf::from_vec(p.encode());
        let d = Packet::decode_buf(&datagram).unwrap();
        assert_eq!(d, p);
        assert_eq!(datagram.ref_count(), 2, "payload shares the datagram allocation");
    }

    #[test]
    fn initial_packet_zero_dst() {
        let p = Packet {
            dst_cid: 0,
            src_cid: 9,
            pkt_num: 0,
            encrypted: false,
            payload: Buf::new(),
        };
        let d = Packet::decode(&p.encode()).unwrap();
        assert_eq!(d.dst_cid, 0);
        assert!(!d.encrypted);
    }

    #[test]
    fn short_packets_rejected() {
        assert!(Packet::decode(&[0u8; 10]).is_err());
        assert!(Packet::decode(&[]).is_err());
    }

    #[test]
    fn header_bytes_match_prefix() {
        let p = Packet {
            dst_cid: 5,
            src_cid: 6,
            pkt_num: 300,
            encrypted: true,
            payload: vec![9, 9].into(),
        };
        let enc = p.encode();
        let hdr = p.header_bytes();
        assert_eq!(&enc[..hdr.len()], &hdr[..]);
    }

    #[test]
    fn nonce_unique_per_pkt_num() {
        let mk = |n| Packet {
            dst_cid: 1,
            src_cid: 2,
            pkt_num: n,
            encrypted: true,
            payload: Buf::new(),
        };
        assert_ne!(mk(1).nonce(), mk(2).nonce());
    }
}
