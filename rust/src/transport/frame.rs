//! Connection frames (the unit of retransmission), protobuf-encoded.
//!
//! `Frame::data` is a [`Buf`]: on receive it is a zero-copy slice of the
//! decrypted packet payload, and on retransmit bookkeeping a frame clone is
//! a reference-count bump instead of a payload copy.

use crate::util::buf::Buf;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::{bail, Result};

/// Frame kinds.
pub const K_HANDSHAKE: u64 = 1;
pub const K_ACK: u64 = 2;
pub const K_STREAM_OPEN: u64 = 3;
pub const K_STREAM_DATA: u64 = 4;
pub const K_STREAM_WINDOW: u64 = 5;
pub const K_STREAM_RESET: u64 = 6;
pub const K_CONN_CLOSE: u64 = 7;
pub const K_PING: u64 = 8;
pub const K_PONG: u64 = 9;
pub const K_PATH_CHALLENGE: u64 = 10;
pub const K_PATH_RESPONSE: u64 = 11;
pub const K_SYN: u64 = 12;
pub const K_SYN_ACK: u64 = 13;

/// A connection frame. One struct with kind-dependent fields (proto3 style).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Frame {
    pub kind: u64,
    /// HANDSHAKE: message index (1..=3). PATH_*: challenge token.
    pub seq: u64,
    /// Stream frames: stream id.
    pub stream_id: u64,
    /// STREAM_DATA: byte offset.
    pub offset: u64,
    /// HANDSHAKE / STREAM_DATA payload.
    pub data: Buf,
    /// STREAM_DATA: sender finished after this segment.
    pub fin: bool,
    /// ACK: largest packet number seen.
    pub largest_ack: u64,
    /// ACK: alternating (gap, run) lengths descending from `largest_ack`,
    /// QUIC-style. First run includes `largest_ack` itself.
    pub ack_ranges: Vec<u64>,
    /// STREAM_WINDOW: additional credit in bytes.
    pub credit: u64,
    /// STREAM_OPEN: protocol name.
    pub proto: String,
    /// CONN_CLOSE / STREAM_RESET: reason.
    pub error: String,
}

impl Frame {
    pub fn handshake(idx: u64, data: Vec<u8>) -> Frame {
        Frame {
            kind: K_HANDSHAKE,
            seq: idx,
            data: data.into(),
            ..Frame::default()
        }
    }

    pub fn stream_open(stream_id: u64, proto: &str) -> Frame {
        Frame {
            kind: K_STREAM_OPEN,
            stream_id,
            proto: proto.to_string(),
            ..Frame::default()
        }
    }

    pub fn stream_data(stream_id: u64, offset: u64, data: Buf, fin: bool) -> Frame {
        Frame {
            kind: K_STREAM_DATA,
            stream_id,
            offset,
            data,
            fin,
            ..Frame::default()
        }
    }

    pub fn stream_window(stream_id: u64, credit: u64) -> Frame {
        Frame {
            kind: K_STREAM_WINDOW,
            stream_id,
            credit,
            ..Frame::default()
        }
    }

    pub fn stream_reset(stream_id: u64, error: &str) -> Frame {
        Frame {
            kind: K_STREAM_RESET,
            stream_id,
            error: error.to_string(),
            ..Frame::default()
        }
    }

    pub fn conn_close(error: &str) -> Frame {
        Frame {
            kind: K_CONN_CLOSE,
            error: error.to_string(),
            ..Frame::default()
        }
    }

    pub fn ping() -> Frame {
        Frame {
            kind: K_PING,
            ..Frame::default()
        }
    }

    pub fn pong() -> Frame {
        Frame {
            kind: K_PONG,
            ..Frame::default()
        }
    }

    pub fn ack(largest: u64, ranges: Vec<u64>) -> Frame {
        Frame {
            kind: K_ACK,
            largest_ack: largest,
            ack_ranges: ranges,
            ..Frame::default()
        }
    }

    pub fn path_challenge(token: u64) -> Frame {
        Frame {
            kind: K_PATH_CHALLENGE,
            seq: token,
            ..Frame::default()
        }
    }

    pub fn path_response(token: u64) -> Frame {
        Frame {
            kind: K_PATH_RESPONSE,
            seq: token,
            ..Frame::default()
        }
    }

    pub fn syn() -> Frame {
        Frame {
            kind: K_SYN,
            ..Frame::default()
        }
    }

    pub fn syn_ack() -> Frame {
        Frame {
            kind: K_SYN_ACK,
            ..Frame::default()
        }
    }

    /// Whether loss of this frame requires retransmission.
    pub fn is_retransmittable(&self) -> bool {
        !matches!(self.kind, K_ACK | K_PONG | K_PATH_RESPONSE)
    }

    /// Whether receipt of this frame elicits an acknowledgment.
    pub fn is_ack_eliciting(&self) -> bool {
        !matches!(self.kind, K_ACK)
    }

    /// Approximate encoded size without encoding (hot-path budgeting).
    pub fn wire_size_hint(&self) -> usize {
        24 + self.data.len() + self.proto.len() + self.error.len() + self.ack_ranges.len() * 3
    }
}

impl Message for Frame {
    fn encode_to(&self, w: &mut PbWriter) {
        w.uint(1, self.kind);
        w.uint(2, self.seq);
        w.uint(3, self.stream_id);
        w.uint(4, self.offset);
        w.bytes(5, &self.data);
        w.boolean(6, self.fin);
        w.uint(7, self.largest_ack);
        w.packed_uints(8, &self.ack_ranges);
        w.uint(9, self.credit);
        w.string(10, &self.proto);
        w.string(11, &self.error);
    }

    fn decode(buf: &[u8]) -> Result<Frame> {
        let mut f = Frame::default();
        PbReader::new(buf).for_each(|fld| {
            match fld.number {
                5 => f.data = Buf::copy_from_slice(fld.as_bytes()?),
                other => decode_common_field(&mut f, other, &fld)?,
            }
            Ok(())
        })?;
        check_kind(&f)?;
        Ok(f)
    }

    /// Zero-copy decode: `data` becomes a slice of `buf`.
    fn decode_buf(buf: &Buf) -> Result<Frame> {
        let mut f = Frame::default();
        PbReader::new(buf.as_slice()).for_each(|fld| {
            match fld.number {
                5 => {
                    fld.as_bytes()?; // wire-type check
                    f.data = buf.slice(fld.data_start..fld.data_start + fld.data.len());
                }
                other => decode_common_field(&mut f, other, &fld)?,
            }
            Ok(())
        })?;
        check_kind(&f)?;
        Ok(f)
    }
}

/// Shared decode arms for every field except 5 (`data`).
fn decode_common_field(f: &mut Frame, number: u32, fld: &crate::wire::pb::Field<'_>) -> Result<()> {
    match number {
        1 => f.kind = fld.as_u64(),
        2 => f.seq = fld.as_u64(),
        3 => f.stream_id = fld.as_u64(),
        4 => f.offset = fld.as_u64(),
        6 => f.fin = fld.as_bool(),
        7 => f.largest_ack = fld.as_u64(),
        8 => f.ack_ranges = fld.packed_uints()?,
        9 => f.credit = fld.as_u64(),
        10 => f.proto = fld.as_string()?,
        11 => f.error = fld.as_string()?,
        _ => {}
    }
    Ok(())
}

fn check_kind(f: &Frame) -> Result<()> {
    if f.kind == 0 || f.kind > K_SYN_ACK {
        bail!("invalid frame kind {}", f.kind);
    }
    Ok(())
}

/// Encode a sequence of frames onto the end of `out` (the packet build path:
/// frames go straight into the datagram buffer, no intermediate payload).
pub fn encode_frames_into(out: &mut Vec<u8>, frames: &[Frame]) {
    for f in frames {
        crate::wire::encode_pooled(f, |body| {
            crate::util::varint::put_length_prefixed(out, body);
        });
    }
}

/// Encode a sequence of frames into a packet payload.
pub fn encode_frames(frames: &[Frame]) -> Vec<u8> {
    let mut out = Vec::with_capacity(frames.iter().map(|f| f.wire_size_hint()).sum());
    encode_frames_into(&mut out, frames);
    out
}

/// Decode a packet payload into frames; `data` fields are zero-copy slices
/// of `buf`.
pub fn decode_frames(buf: &Buf) -> Result<Vec<Frame>> {
    let mut r = crate::util::varint::Reader::new(buf.as_slice());
    let mut out = Vec::new();
    while !r.is_empty() {
        let body = r.length_prefixed()?;
        let start = r.pos - body.len();
        out.push(Frame::decode_buf(&buf.slice(start..r.pos))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrips() {
        let frames = vec![
            Frame::handshake(1, vec![1, 2, 3]),
            Frame::stream_open(7, "/lattica/rpc/1"),
            Frame::stream_data(7, 1000, vec![9; 100].into(), true),
            Frame::stream_window(7, 65536),
            Frame::stream_reset(7, "cancelled"),
            Frame::conn_close("bye"),
            Frame::ping(),
            Frame::pong(),
            Frame::ack(42, vec![3, 2, 5]),
            Frame::path_challenge(0xDEAD),
            Frame::path_response(0xDEAD),
            Frame::syn(),
            Frame::syn_ack(),
        ];
        for f in &frames {
            let enc = f.encode();
            assert_eq!(&Frame::decode(&enc).unwrap(), f, "frame {f:?}");
        }
        // Batch roundtrip.
        let payload = Buf::from_vec(encode_frames(&frames));
        assert_eq!(decode_frames(&payload).unwrap(), frames);
    }

    #[test]
    fn decode_frames_data_is_zero_copy() {
        let frames = vec![
            Frame::stream_data(1, 0, vec![7u8; 200].into(), false),
            Frame::stream_data(1, 200, vec![8u8; 100].into(), true),
        ];
        let payload = Buf::from_vec(encode_frames(&frames));
        let decoded = decode_frames(&payload).unwrap();
        assert_eq!(decoded, frames);
        // Both data fields share the payload allocation (2 slices + payload).
        assert_eq!(payload.ref_count(), 3);
    }

    #[test]
    fn invalid_kind_rejected() {
        let f = Frame {
            kind: 99,
            ..Frame::default()
        };
        assert!(Frame::decode(&f.encode()).is_err());
        assert!(Frame::decode(&[]).is_err()); // kind 0
    }

    #[test]
    fn ack_properties() {
        assert!(!Frame::ack(1, vec![]).is_retransmittable());
        assert!(!Frame::ack(1, vec![]).is_ack_eliciting());
        assert!(Frame::stream_data(1, 0, Buf::new(), false).is_ack_eliciting());
        assert!(Frame::ping().is_retransmittable());
        assert!(!Frame::pong().is_retransmittable());
    }

    #[test]
    fn truncated_batch_fails() {
        let payload = encode_frames(&[Frame::ping(), Frame::pong()]);
        let truncated = Buf::from_vec(payload[..payload.len() - 1].to_vec());
        assert!(decode_frames(&truncated).is_err());
    }
}
