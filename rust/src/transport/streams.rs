//! Per-stream send/receive state: ordered byte delivery with offset-based
//! reassembly, credit flow control, and length-prefixed message framing.
//!
//! Upper layers exchange discrete *messages*; the stream layer length-
//! prefixes them into the byte stream and re-parses on the receive side, so
//! protocols never see fragmentation.
//!
//! Zero-copy: queued data, in-flight chunks and reassembly segments are all
//! [`Buf`] views. `take_chunk` slices the front buffer, retransmission
//! requeues slices, and the receive side returns messages as slices of the
//! decrypted packet payload whenever a message does not span segments; only
//! a partial message at the head of the stream is ever copied (into the
//! spill buffer).

use crate::util::buf::Buf;
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};

/// Default per-stream receive window (credit granted to the peer).
pub const DEFAULT_WINDOW: u64 = 1 << 20; // 1 MiB

/// Grant more credit when consumed beyond this fraction of the window.
pub const CREDIT_REFRESH_FRACTION: f64 = 0.5;

/// Messages at or below this size are copied into one framed buffer on
/// write (two tiny queue entries would cost more than the copy); larger
/// messages are queued as a shared [`Buf`] behind their length prefix.
pub const SHARE_THRESHOLD: usize = 512;

/// Sending half.
#[derive(Debug)]
pub struct SendStream {
    /// Next offset to assign to new data.
    pub write_offset: u64,
    /// Data accepted from the application but not yet packetized,
    /// as (offset, bytes).
    pub pending: VecDeque<(u64, Buf)>,
    /// Cursor into `pending.front()` — lets take_chunk slice the front
    /// buffer without popping it until fully consumed.
    front_pos: usize,
    /// Peer-granted credit limit (absolute offset we may send up to).
    pub credit_limit: u64,
    /// Highest offset handed to the packetizer.
    pub sent_offset: u64,
    /// FIN queued / sent.
    pub fin_queued: bool,
    pub fin_sent: bool,
    /// Stream reset/closed.
    pub closed: bool,
}

impl SendStream {
    pub fn new() -> SendStream {
        SendStream {
            write_offset: 0,
            pending: VecDeque::new(),
            front_pos: 0,
            credit_limit: DEFAULT_WINDOW,
            sent_offset: 0,
            fin_queued: false,
            fin_sent: false,
            closed: false,
        }
    }

    /// Queue a message (length-prefixed into the byte stream, one copy).
    pub fn write_msg(&mut self, msg: &[u8]) {
        debug_assert!(!self.fin_queued && !self.closed);
        let mut framed = Vec::with_capacity(msg.len() + 5);
        crate::util::varint::put_length_prefixed(&mut framed, msg);
        let off = self.write_offset;
        self.write_offset += framed.len() as u64;
        self.pending.push_back((off, Buf::from_vec(framed)));
    }

    /// Queue an owned message. Large messages are queued zero-copy (the
    /// length prefix and the payload become adjacent queue entries); small
    /// ones take the [`write_msg`] copy path.
    ///
    /// [`write_msg`]: SendStream::write_msg
    pub fn write_msg_buf(&mut self, msg: Buf) {
        debug_assert!(!self.fin_queued && !self.closed);
        if msg.len() <= SHARE_THRESHOLD {
            self.write_msg(&msg);
            return;
        }
        let mut prefix = Vec::with_capacity(5);
        crate::util::varint::put_uvarint(&mut prefix, msg.len() as u64);
        let off = self.write_offset;
        self.write_offset += prefix.len() as u64;
        self.pending.push_back((off, Buf::from_vec(prefix)));
        let off = self.write_offset;
        self.write_offset += msg.len() as u64;
        self.pending.push_back((off, msg));
    }

    /// Queue raw bytes (no framing) — used by tests.
    pub fn write_raw(&mut self, data: &[u8]) {
        let off = self.write_offset;
        self.write_offset += data.len() as u64;
        self.pending.push_back((off, Buf::copy_from_slice(data)));
    }

    /// Mark the stream finished once pending data drains.
    pub fn finish(&mut self) {
        self.fin_queued = true;
    }

    /// Bytes currently waiting (application backlog — the backpressure
    /// signal surfaced to RPC writers).
    pub fn backlog(&self) -> u64 {
        self.pending.iter().map(|(_, d)| d.len() as u64).sum::<u64>()
            - self.front_pos as u64
    }

    /// Whether flow-control credit allows sending more.
    pub fn can_send(&self) -> bool {
        !self.closed && self.sent_offset < self.credit_limit && !self.pending.is_empty()
    }

    /// Whether a FIN still needs to go out.
    pub fn fin_pending(&self) -> bool {
        self.fin_queued && !self.fin_sent && self.pending.is_empty() && !self.closed
    }

    /// Take up to `max_bytes` of sendable data respecting credit.
    /// Returns (offset, data, fin). The data is a zero-copy slice of the
    /// queued buffer.
    pub fn take_chunk(&mut self, max_bytes: usize) -> Option<(u64, Buf, bool)> {
        if self.closed {
            return None;
        }
        if self.pending.is_empty() {
            if self.fin_pending() {
                self.fin_sent = true;
                return Some((self.sent_offset, Buf::new(), true));
            }
            return None;
        }
        let credit_room = self.credit_limit.saturating_sub(self.sent_offset);
        if credit_room == 0 {
            return None;
        }
        let budget = (max_bytes as u64).min(credit_room) as usize;
        let (front_off, front_len) = {
            let (o, d) = self.pending.front().unwrap();
            (*o, d.len())
        };
        let avail = front_len - self.front_pos;
        let take = avail.min(budget);
        let off = front_off + self.front_pos as u64;
        let data = {
            let (_, d) = self.pending.front().unwrap();
            d.slice(self.front_pos..self.front_pos + take)
        };
        self.front_pos += take;
        if self.front_pos == front_len {
            self.pending.pop_front();
            self.front_pos = 0;
        }
        // `pending` may be non-contiguous after retransmission gaps, so
        // sent_offset tracks the high-water mark for credit accounting.
        self.sent_offset = self.sent_offset.max(off + data.len() as u64);
        let fin = self.pending.is_empty() && self.fin_queued && self.sent_offset == self.write_offset;
        if fin {
            self.fin_sent = true;
        }
        Some((off, data, fin))
    }

    /// Re-queue data after loss (frame-level retransmission).
    pub fn requeue(&mut self, offset: u64, data: Buf, fin: bool) {
        if self.closed {
            return;
        }
        if fin {
            self.fin_sent = false;
            self.fin_queued = true;
        }
        if data.is_empty() && !fin {
            return;
        }
        if !data.is_empty() {
            // Materialize the front cursor first: the insertion below may
            // displace the front element the cursor refers to.
            if self.front_pos > 0 {
                if let Some((off0, data0)) = self.pending.pop_front() {
                    let rest = data0.slice(self.front_pos..);
                    if !rest.is_empty() {
                        self.pending.push_front((off0 + self.front_pos as u64, rest));
                    }
                }
                self.front_pos = 0;
            }
            // Fast path: non-overlapping insert at the tail or head (the
            // overwhelmingly common retransmission patterns) skips the
            // full normalize rebuild.
            let end = offset + data.len() as u64;
            let tail_ok = self
                .pending
                .back()
                .map_or(true, |(o, d)| o + d.len() as u64 <= offset);
            let head_ok = self
                .pending
                .front()
                .map_or(false, |(o, _)| end <= *o && self.front_pos == 0);
            if tail_ok {
                self.pending.push_back((offset, data));
                self.sent_offset = self.sent_offset.min(offset);
            } else if head_ok {
                self.pending.push_front((offset, data));
                self.sent_offset = self.sent_offset.min(offset);
            } else {
                let pos = self
                    .pending
                    .iter()
                    .position(|(o, _)| *o > offset)
                    .unwrap_or(self.pending.len());
                self.pending.insert(pos, (offset, data));
                self.sent_offset = self.sent_offset.min(offset);
                // Rebuild contiguity: merge overlapping spans.
                self.normalize();
            }
        }
    }

    fn normalize(&mut self) {
        debug_assert_eq!(self.front_pos, 0, "cursor materialized by requeue");
        // Ensure pending is sorted and non-overlapping (drop duplicate spans).
        let mut items: Vec<(u64, Buf)> = self.pending.drain(..).collect();
        items.sort_by_key(|(o, _)| *o);
        let mut out: VecDeque<(u64, Buf)> = VecDeque::with_capacity(items.len());
        let mut covered = self.sent_offset;
        for (off, data) in items {
            let end = off + data.len() as u64;
            if end <= covered {
                continue; // fully duplicate
            }
            if off >= covered {
                covered = end;
                out.push_back((off, data));
            } else {
                // Partial overlap: trim the front (zero-copy slice).
                let skip = (covered - off) as usize;
                let trimmed = data.slice(skip..);
                let new_off = covered;
                covered = end;
                out.push_back((new_off, trimmed));
            }
        }
        self.pending = out;
    }
}

/// Receiving half.
#[derive(Debug)]
pub struct RecvStream {
    /// Contiguous bytes delivered to the message parser.
    pub read_offset: u64,
    /// Out-of-order segments: offset → bytes (zero-copy packet slices).
    segments: BTreeMap<u64, Buf>,
    /// Spill buffer: a partial message at the head of the stream, or bytes
    /// of a message that spans segments. Empty on the hot path.
    buffer: Vec<u8>,
    /// Absolute credit limit we granted the peer.
    pub credit_granted: u64,
    /// FIN offset when known.
    pub fin_offset: Option<u64>,
    pub finished: bool,
    pub reset: bool,
}

impl RecvStream {
    pub fn new() -> RecvStream {
        RecvStream {
            read_offset: 0,
            segments: BTreeMap::new(),
            buffer: Vec::new(),
            credit_granted: DEFAULT_WINDOW,
            fin_offset: None,
            finished: false,
            reset: false,
        }
    }

    /// Ingest a STREAM_DATA segment; returns complete messages, plus whether
    /// the stream finished cleanly. Messages contained in one segment are
    /// zero-copy slices of it.
    pub fn on_data(
        &mut self,
        offset: u64,
        data: Buf,
        fin: bool,
    ) -> Result<(Vec<Buf>, bool)> {
        if self.reset {
            return Ok((Vec::new(), false));
        }
        if fin {
            let fo = offset + data.len() as u64;
            if let Some(prev) = self.fin_offset {
                anyhow::ensure!(prev == fo, "conflicting FIN offsets");
            }
            self.fin_offset = Some(fo);
        }
        if !data.is_empty() {
            let end = offset + data.len() as u64;
            if end > self.read_offset {
                // Trim already-delivered prefix (zero-copy).
                let (off, dat) = if offset < self.read_offset {
                    let skip = (self.read_offset - offset) as usize;
                    (self.read_offset, data.slice(skip..))
                } else {
                    (offset, data)
                };
                // Keep the longer of duplicates at the same offset.
                match self.segments.get(&off) {
                    Some(existing) if existing.len() >= dat.len() => {}
                    _ => {
                        self.segments.insert(off, dat);
                    }
                }
            }
        }
        let mut msgs: Vec<Buf> = Vec::new();
        // Drain contiguous segments. While the spill buffer is empty, parse
        // complete messages straight out of each segment (zero-copy); only a
        // trailing partial message spills.
        loop {
            let Some((&off, _)) = self.segments.iter().next() else {
                break;
            };
            if off > self.read_offset {
                break;
            }
            let (off, seg) = self.segments.pop_first().unwrap();
            let end = off + seg.len() as u64;
            if end <= self.read_offset {
                continue; // fully duplicate
            }
            let skip = (self.read_offset - off) as usize;
            let seg = seg.slice(skip..);
            self.read_offset = end;
            if self.buffer.is_empty() {
                let mut pos = 0usize;
                loop {
                    match crate::util::varint::get_uvarint(&seg[pos..]) {
                        Ok((len, n)) => {
                            let total = n + len as usize;
                            if seg.len() - pos >= total {
                                msgs.push(seg.slice(pos + n..pos + total));
                                pos += total;
                            } else {
                                break;
                            }
                        }
                        Err(_) => break, // need more bytes (or empty)
                    }
                }
                if pos < seg.len() {
                    self.buffer.extend_from_slice(&seg[pos..]);
                }
            } else {
                self.buffer.extend_from_slice(&seg);
            }
        }
        // Cold path: messages spanning segment boundaries sit in the spill
        // buffer; parse and copy them out.
        let mut pos = 0usize;
        loop {
            match crate::util::varint::get_uvarint(&self.buffer[pos..]) {
                Ok((len, n)) => {
                    let total = n + len as usize;
                    if self.buffer.len() - pos >= total {
                        msgs.push(Buf::copy_from_slice(&self.buffer[pos + n..pos + total]));
                        pos += total;
                    } else {
                        break;
                    }
                }
                Err(_) => break, // need more bytes for the varint itself
            }
        }
        if pos > 0 {
            self.buffer.drain(..pos);
        }
        let finished_now = if let Some(fo) = self.fin_offset {
            if self.read_offset == fo && !self.finished {
                self.finished = true;
                anyhow::ensure!(
                    self.buffer.is_empty(),
                    "stream finished with partial message"
                );
                true
            } else {
                false
            }
        } else {
            false
        };
        Ok((msgs, finished_now))
    }

    /// Whether we should grant more credit, and the new absolute limit.
    pub fn credit_update(&mut self) -> Option<u64> {
        let consumed_beyond = self
            .credit_granted
            .saturating_sub(self.read_offset);
        if (consumed_beyond as f64) < DEFAULT_WINDOW as f64 * CREDIT_REFRESH_FRACTION {
            self.credit_granted = self.read_offset + DEFAULT_WINDOW;
            Some(self.credit_granted)
        } else {
            None
        }
    }

    /// Buffered byte count (receive-side pressure).
    pub fn buffered(&self) -> usize {
        self.buffer.len() + self.segments.values().map(|v| v.len()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_roundtrip_in_order() {
        let mut tx = SendStream::new();
        let mut rx = RecvStream::new();
        tx.write_msg(b"hello");
        tx.write_msg(b"world");
        let mut msgs = Vec::new();
        while let Some((off, data, fin)) = tx.take_chunk(1400) {
            let (m, _) = rx.on_data(off, data, fin).unwrap();
            msgs.extend(m);
        }
        assert_eq!(msgs, vec![b"hello".to_vec(), b"world".to_vec()]);
    }

    #[test]
    fn single_segment_messages_are_zero_copy() {
        let mut rx = RecvStream::new();
        let mut framed = Vec::new();
        crate::util::varint::put_length_prefixed(&mut framed, b"alpha");
        crate::util::varint::put_length_prefixed(&mut framed, b"beta");
        let seg = Buf::from_vec(framed);
        let (msgs, _) = rx.on_data(0, seg.clone(), false).unwrap();
        assert_eq!(msgs, vec![b"alpha".to_vec(), b"beta".to_vec()]);
        // Both messages are slices of the ingested segment.
        assert_eq!(seg.ref_count(), 3);
        assert_eq!(rx.buffered(), 0);
    }

    #[test]
    fn large_write_is_shared_not_copied() {
        let mut tx = SendStream::new();
        let mut rx = RecvStream::new();
        let payload = Buf::from_vec(vec![3u8; 4 * SHARE_THRESHOLD]);
        tx.write_msg_buf(payload.clone());
        // The payload entry in the queue shares our allocation.
        assert_eq!(payload.ref_count(), 2);
        let mut got = Vec::new();
        while let Some((off, data, fin)) = tx.take_chunk(1000) {
            let (m, _) = rx.on_data(off, data, fin).unwrap();
            got.extend(m);
        }
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], payload);
    }

    #[test]
    fn small_write_buf_takes_copy_path() {
        let mut tx = SendStream::new();
        tx.write_msg_buf(Buf::from_vec(vec![1u8; 8]));
        assert_eq!(tx.pending.len(), 1, "prefix and payload share one buffer");
        let mut tx2 = SendStream::new();
        tx2.write_msg_buf(Buf::from_vec(vec![1u8; SHARE_THRESHOLD + 1]));
        assert_eq!(tx2.pending.len(), 2, "large payload queued zero-copy");
        // Offsets are contiguous across the split entries.
        assert_eq!(tx2.pending[0].0 + tx2.pending[0].1.len() as u64, tx2.pending[1].0);
    }

    #[test]
    fn fragmentation_and_reassembly() {
        let mut tx = SendStream::new();
        let mut rx = RecvStream::new();
        let big: Vec<u8> = (0..10_000).map(|i| (i % 256) as u8).collect();
        tx.write_msg(&big);
        let mut chunks = Vec::new();
        while let Some(c) = tx.take_chunk(1000) {
            chunks.push(c);
        }
        assert!(chunks.len() >= 10);
        // Deliver out of order.
        chunks.reverse();
        let mut got = Vec::new();
        for (off, data, fin) in chunks {
            let (m, _) = rx.on_data(off, data, fin).unwrap();
            got.extend(m);
        }
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], big);
    }

    #[test]
    fn duplicates_ignored() {
        let mut tx = SendStream::new();
        let mut rx = RecvStream::new();
        tx.write_msg(b"abcdef");
        let (off, data, fin) = tx.take_chunk(1400).unwrap();
        let (m1, _) = rx.on_data(off, data.clone(), fin).unwrap();
        let (m2, _) = rx.on_data(off, data, fin).unwrap();
        assert_eq!(m1.len(), 1);
        assert!(m2.is_empty());
    }

    #[test]
    fn flow_control_blocks_and_credit_unblocks() {
        let mut tx = SendStream::new();
        tx.credit_limit = 10;
        tx.write_raw(&[0u8; 100]);
        let (_, d1, _) = tx.take_chunk(1400).unwrap();
        assert_eq!(d1.len(), 10);
        assert!(tx.take_chunk(1400).is_none(), "credit exhausted");
        tx.credit_limit = 50;
        let (_, d2, _) = tx.take_chunk(1400).unwrap();
        assert_eq!(d2.len(), 40);
    }

    #[test]
    fn fin_delivered_once_data_complete() {
        let mut tx = SendStream::new();
        let mut rx = RecvStream::new();
        tx.write_msg(b"bye");
        tx.finish();
        let (off, data, fin) = tx.take_chunk(1400).unwrap();
        assert!(fin);
        let (msgs, finished) = rx.on_data(off, data, fin).unwrap();
        assert_eq!(msgs.len(), 1);
        assert!(finished);
        assert!(rx.finished);
    }

    #[test]
    fn fin_out_of_order() {
        let mut rx = RecvStream::new();
        // FIN segment arrives before the middle data.
        let mut framed = Vec::new();
        crate::util::varint::put_length_prefixed(&mut framed, b"xyz");
        let (a, b) = framed.split_at(2);
        let (_, fin1) = rx.on_data(2, b.into(), true).unwrap();
        assert!(!fin1);
        let (msgs, fin2) = rx.on_data(0, a.into(), false).unwrap();
        assert!(fin2);
        assert_eq!(msgs, vec![b"xyz".to_vec()]);
    }

    #[test]
    fn requeue_after_loss() {
        let mut tx = SendStream::new();
        let mut rx = RecvStream::new();
        tx.write_msg(&vec![7u8; 3000]);
        let c1 = tx.take_chunk(1000).unwrap();
        let c2 = tx.take_chunk(1000).unwrap();
        let c3 = tx.take_chunk(1000).unwrap();
        let c4 = tx.take_chunk(1000).unwrap();
        assert!(tx.take_chunk(1000).is_none());
        // c2 "lost": requeue and retransmit.
        tx.requeue(c2.0, c2.1.clone(), c2.2);
        let c2r = tx.take_chunk(1000).unwrap();
        assert_eq!(c2r.0, c2.0);
        assert_eq!(c2r.1, c2.1);
        for (off, data, fin) in [c1, c2r, c3, c4] {
            let _ = rx.on_data(off, data, fin).unwrap();
        }
        assert_eq!(rx.buffered(), 0);
        assert_eq!(rx.read_offset, 3000 + 2); // 2-byte varint length prefix
    }

    /// Regression: requeued spans that partially overlap live pending data
    /// must be trimmed byte-for-byte (normalize path, `streams.rs` overlap
    /// trimming). The receiver must see exactly the original byte stream.
    #[test]
    fn requeue_partial_overlap_trims_exactly() {
        let mut tx = SendStream::new();
        let mut rx = RecvStream::new();
        let msg: Vec<u8> = (0..5000u32).map(|i| (i % 251) as u8).collect();
        tx.write_msg(&msg);
        let c1 = tx.take_chunk(1000).unwrap();
        let c2 = tx.take_chunk(1000).unwrap();
        let c3 = tx.take_chunk(1000).unwrap();
        // Deliver c1 only; "lose" c2 and c3.
        let _ = rx.on_data(c1.0, c1.1.clone(), c1.2).unwrap();
        // Requeue out of order and overlapping: c3 first, then a span that
        // overlaps both c2's range and the front of c3's range.
        tx.requeue(c3.0, c3.1.clone(), c3.2);
        let mut overlap = c2.1.to_vec();
        overlap.extend_from_slice(&c3.1[..500]);
        tx.requeue(c2.0, Buf::from_vec(overlap), false);
        // Drain everything that's left and feed it to the receiver.
        let mut got = Vec::new();
        while let Some((off, data, fin)) = tx.take_chunk(1000) {
            let (m, _) = rx.on_data(off, data, fin).unwrap();
            got.extend(m);
        }
        // The full message must reassemble exactly once, byte-for-byte.
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], msg);
        assert_eq!(rx.read_offset, tx.write_offset);
        assert_eq!(rx.buffered(), 0);
    }

    /// Regression: the requeue path after a partially-consumed front buffer
    /// (front_pos > 0) must materialize the cursor without losing bytes.
    #[test]
    fn requeue_with_partial_front_cursor() {
        let mut tx = SendStream::new();
        let mut rx = RecvStream::new();
        let msg: Vec<u8> = (0..4000u32).map(|i| (i * 7 % 256) as u8).collect();
        tx.write_msg(&msg);
        let c1 = tx.take_chunk(1500).unwrap();
        // Partially consume the front buffer so the cursor is mid-buffer.
        let c2 = tx.take_chunk(700).unwrap();
        // Now requeue c1 (head insert) while front_pos > 0.
        tx.requeue(c1.0, c1.1.clone(), c1.2);
        let mut delivered = vec![c2];
        while let Some(c) = tx.take_chunk(1500) {
            delivered.push(c);
        }
        let mut got = Vec::new();
        for (off, data, fin) in delivered {
            let (m, _) = rx.on_data(off, data, fin).unwrap();
            got.extend(m);
        }
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], msg);
    }

    /// Regression: out-of-order delivery where segments overlap the
    /// already-delivered prefix and each other (`on_data` skip/trim logic)
    /// must reproduce the byte stream exactly.
    #[test]
    fn out_of_order_overlapping_segments_trim_exactly() {
        let mut rx = RecvStream::new();
        let mut stream = Vec::new();
        let m1: Vec<u8> = (0..900u32).map(|i| (i % 199) as u8).collect();
        let m2: Vec<u8> = (0..700u32).map(|i| (i % 83) as u8).collect();
        crate::util::varint::put_length_prefixed(&mut stream, &m1);
        crate::util::varint::put_length_prefixed(&mut stream, &m2);
        let whole = Buf::from_vec(stream);
        let n = whole.len();
        // Segment plan (all ranges overlap a neighbour):
        //   [300..700) arrives first (buffered out of order)
        //   [0..400)   delivers 0..700 once contiguous
        //   [250..650) fully duplicate after delivery
        //   [600..n)   overlaps the delivered prefix by 100 bytes
        let mut msgs = Vec::new();
        let (m, _) = rx.on_data(300, whole.slice(300..700), false).unwrap();
        msgs.extend(m);
        assert_eq!(rx.read_offset, 0, "gap: nothing contiguous yet");
        let (m, _) = rx.on_data(0, whole.slice(..400), false).unwrap();
        msgs.extend(m);
        assert_eq!(rx.read_offset, 700);
        let (m, _) = rx.on_data(250, whole.slice(250..650), false).unwrap();
        assert!(m.is_empty(), "fully duplicate segment delivers nothing");
        let (m, _) = rx.on_data(600, whole.slice(600..), false).unwrap();
        msgs.extend(m);
        assert_eq!(rx.read_offset, n as u64);
        assert_eq!(msgs, vec![m1, m2]);
        assert_eq!(rx.buffered(), 0);
    }

    #[test]
    fn credit_update_fires_after_consumption() {
        let mut rx = RecvStream::new();
        assert!(
            rx.credit_update().is_none(),
            "full window outstanding: no refresh needed"
        );
        // Consume most of the window.
        let data = vec![0u8; (DEFAULT_WINDOW / 2 + 100) as usize];
        let mut framed = Vec::new();
        crate::util::varint::put_length_prefixed(&mut framed, &data);
        let _ = rx.on_data(0, framed.into(), false).unwrap();
        let update = rx.credit_update();
        assert!(update.is_some());
        assert!(update.unwrap() > DEFAULT_WINDOW);
    }

    #[test]
    fn partial_message_at_fin_errors() {
        let mut rx = RecvStream::new();
        let mut framed = Vec::new();
        crate::util::varint::put_length_prefixed(&mut framed, b"hello");
        framed.truncate(3); // cut mid-message
        assert!(rx.on_data(0, framed.into(), true).is_err());
    }

    #[test]
    fn backlog_reflects_pending() {
        let mut tx = SendStream::new();
        assert_eq!(tx.backlog(), 0);
        tx.write_msg(&vec![0u8; 500]);
        assert!(tx.backlog() >= 500);
        let _ = tx.take_chunk(10_000);
        assert_eq!(tx.backlog(), 0);
    }
}
