//! Reliable, encrypted, multiplexed connections over simulated datagrams.
//!
//! One engine implements both of the paper's transports:
//!
//! * **QUIC-like** (`Proto::QuicLike`): handshake frames ride the first
//!   packets, so application data flows after ~1 RTT.
//! * **TCP-like** (`Proto::TcpLike`): an extra SYN/SYN-ACK round trip runs
//!   before the Noise handshake (modelling TCP connect + security upgrade +
//!   mux negotiation), and every frame pays a small extra header tax.
//!
//! The engine is *sans-io*: [`connection::Connection`] consumes packets and
//! timer ticks and produces packets plus [`ConnEvent`]s; the swarm layer
//! moves bytes between connections and the simulator (or a relay circuit —
//! connections are path-agnostic, which is what lets DCUtR migrate a relayed
//! connection onto a punched direct path without disturbing open streams).
//!
//! Reliability: QUIC-style frame-level retransmission with packet-number
//! acks (gap ranges), RACK-style loss detection (packet + time thresholds,
//! RTO as last resort), pluggable congestion control (NewReno / CUBIC /
//! fixed-window, see [`cc`]), token-bucket pacing ([`pacer`]), a
//! priority-aware stream scheduler ([`sched`]), and per-stream credit flow
//! control (the paper's "adaptive backpressure": writers observe
//! acknowledgments/queue depth, readers grant credit).

pub mod cc;
pub mod frame;
pub mod packet;
pub mod pacer;
pub mod rtt;
pub mod sched;
pub mod streams;
pub mod connection;

pub use cc::CcAlgorithm;
pub use connection::{ConnEvent, Connection, ConnectionConfig, Role};
pub use frame::Frame;
pub use sched::TrafficClass;

/// Transport profile: the observable differences between the two transports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransportProfile {
    /// Extra round trips before the Noise handshake may start.
    pub extra_handshake_rtts: u8,
    /// Additional per-packet byte overhead (framing tax).
    pub per_packet_overhead: usize,
}

impl TransportProfile {
    pub const QUIC_LIKE: TransportProfile = TransportProfile {
        extra_handshake_rtts: 0,
        per_packet_overhead: 0,
    };

    /// TCP connect (1 RTT) before security; ~20 B/packet extra headers
    /// (TCP header vs UDP + mux framing).
    pub const TCP_LIKE: TransportProfile = TransportProfile {
        extra_handshake_rtts: 1,
        per_packet_overhead: 20,
    };

    pub fn for_proto(p: crate::multiaddr::Proto) -> TransportProfile {
        match p {
            crate::multiaddr::Proto::QuicLike => Self::QUIC_LIKE,
            crate::multiaddr::Proto::TcpLike => Self::TCP_LIKE,
        }
    }
}
