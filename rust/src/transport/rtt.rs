//! RTT estimation and retransmission timeout (RFC 6298-style smoothing).

use crate::netsim::{Time, MILLI};

/// How long a receiver may sit on a delayed ACK (the connection arms its
/// ACK deadline at 1 ms; keep a little slack on top).
pub const MAX_ACK_DELAY: Time = 2 * MILLI;

#[derive(Clone, Debug)]
pub struct RttEstimator {
    srtt: Option<Time>,
    rttvar: Time,
    /// Minimum observed RTT (path floor).
    pub min_rtt: Time,
    latest: Time,
    /// RTO before any sample, and the adaptive floor afterwards. Tunneled
    /// (relayed) connections set this high: the carrier already
    /// retransmits, and queueing delay would otherwise trigger spurious
    /// inner retransmissions (the TCP-over-TCP meltdown).
    pub initial_rto: Time,
    pub min_rto: Time,
}

impl Default for RttEstimator {
    fn default() -> Self {
        Self::new()
    }
}

impl RttEstimator {
    pub fn new() -> RttEstimator {
        RttEstimator {
            srtt: None,
            rttvar: 0,
            min_rtt: Time::MAX,
            latest: 0,
            initial_rto: 100 * MILLI,
            min_rto: 2 * MILLI,
        }
    }

    /// Record a sample from an acked packet.
    pub fn on_sample(&mut self, rtt: Time) {
        self.latest = rtt;
        self.min_rtt = self.min_rtt.min(rtt);
        match self.srtt {
            None => {
                self.srtt = Some(rtt);
                self.rttvar = rtt / 2;
            }
            Some(srtt) => {
                let diff = srtt.abs_diff(rtt);
                self.rttvar = (3 * self.rttvar + diff) / 4;
                self.srtt = Some((7 * srtt + rtt) / 8);
            }
        }
    }

    pub fn srtt(&self) -> Time {
        self.srtt.unwrap_or(100 * MILLI)
    }

    pub fn latest(&self) -> Time {
        self.latest
    }

    /// Retransmission timeout: srtt + 4·rttvar + a delayed-ACK allowance,
    /// with a configurable floor, and `initial_rto` before any sample.
    /// The allowance keeps a stable path's RTO strictly above the RACK
    /// tail-loss threshold (9/8·srtt), so the timeout stays the last
    /// resort even when rttvar converges to zero.
    pub fn rto(&self) -> Time {
        match self.srtt {
            None => self.initial_rto,
            Some(srtt) => (srtt + 4 * self.rttvar + MAX_ACK_DELAY).max(self.min_rto),
        }
    }

    pub fn has_sample(&self) -> bool {
        self.srtt.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_rto_before_samples() {
        let r = RttEstimator::new();
        assert_eq!(r.rto(), 100 * MILLI);
        assert!(!r.has_sample());
        let mut t = RttEstimator::new();
        t.initial_rto = 1_000 * MILLI;
        t.min_rto = 200 * MILLI;
        assert_eq!(t.rto(), 1_000 * MILLI);
        t.on_sample(10 * MILLI);
        assert_eq!(t.rto(), 200 * MILLI);
    }

    #[test]
    fn converges_to_stable_rtt() {
        let mut r = RttEstimator::new();
        for _ in 0..50 {
            r.on_sample(20 * MILLI);
        }
        assert_eq!(r.srtt(), 20 * MILLI);
        assert!(r.rto() >= 20 * MILLI && r.rto() <= 30 * MILLI, "rto={}", r.rto());
        assert_eq!(r.min_rtt, 20 * MILLI);
    }

    #[test]
    fn variance_raises_rto() {
        let mut stable = RttEstimator::new();
        let mut jittery = RttEstimator::new();
        for i in 0..50 {
            stable.on_sample(20 * MILLI);
            jittery.on_sample(if i % 2 == 0 { 10 * MILLI } else { 30 * MILLI });
        }
        assert!(jittery.rto() > stable.rto());
    }

    #[test]
    fn rto_floor() {
        let mut r = RttEstimator::new();
        for _ in 0..10 {
            r.on_sample(10_000); // 10 µs loopback
        }
        assert!(r.rto() >= 2 * MILLI);
    }
}
