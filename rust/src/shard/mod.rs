//! Sharded inference: shard servers hosting layer ranges, and a
//! shard-aware pipeline client with replica failover (Fig. 1(4)).
//!
//! A request enters at shard 0 (embed + first layers); activations hop
//! between shards as RPC tensor payloads; the last shard applies the
//! logits head and the next-token distribution returns to the caller.
//! Shards are replicated: each pipeline stage is a [`Stub`] over its
//! replica set, so a failed hop retries on an alternate replica (with
//! backoff, per-hop deadlines and sticky target preference) without any
//! failover logic in this module.
//!
//! The server side is a registered service
//! ([`ShardServer::into_service`]), not an `App` match arm.

use crate::identity::PeerId;
use crate::netsim::{Net, Time, MILLI, SECOND};
use crate::node::LatticaNode;
use crate::rpc::{CallOptions, Outcome, RetryPolicy, RpcEvent, Service, Status, Stub};
use crate::runtime::{Engine, Tensor};
use crate::util::varint;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

pub const SHARD_SERVICE: &str = "shard";

/// Upper bound on the token count a [`ShardRequest`] may carry; caps the
/// decode-side preallocation against hostile length prefixes.
pub const MAX_TOKENS: usize = 1 << 20;

/// Request payload for the `forward` method.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardRequest {
    /// Request id assigned by the entry client (for tracing).
    pub request_id: u64,
    /// Tokens (only shard 0 uses this) or empty.
    pub tokens: Vec<i32>,
    /// Hidden activation (shards > 0), empty for shard 0.
    pub hidden: Option<Tensor>,
}

impl ShardRequest {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::put_uvarint(&mut out, self.request_id);
        varint::put_uvarint(&mut out, self.tokens.len() as u64);
        for &t in &self.tokens {
            varint::put_uvarint(&mut out, t as u64);
        }
        match &self.hidden {
            Some(h) => {
                out.push(1);
                out.extend_from_slice(&h.encode());
            }
            None => out.push(0),
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ShardRequest> {
        let mut r = varint::Reader::new(buf);
        let request_id = r.uvarint()?;
        let n = r.uvarint()? as usize;
        // The count is attacker-controlled: bound it, and never preallocate
        // more slots than the remaining bytes could possibly encode (each
        // token takes at least one byte).
        anyhow::ensure!(n <= MAX_TOKENS, "token count {n} exceeds cap {MAX_TOKENS}");
        let mut tokens = Vec::with_capacity(n.min(r.remaining()));
        for _ in 0..n {
            tokens.push(r.uvarint()? as i32);
        }
        let flag = r.take(1)?[0];
        let hidden = if flag == 1 {
            Some(Tensor::decode(&buf[r.pos..])?)
        } else {
            None
        };
        Ok(ShardRequest {
            request_id,
            tokens,
            hidden,
        })
    }
}

/// A shard server app: owns a layer range and (for the edge shards) the
/// embedding/logits heads. Parameters are the node's local copy (fetched
/// via Bitswap in the full pipeline).
pub struct ShardServer {
    pub engine: Rc<RefCell<Engine>>,
    /// Layer range [start, end).
    pub layers: (usize, usize),
    pub is_first: bool,
    pub is_last: bool,
    /// Full parameter list (only the owned slices are used).
    pub params: Vec<Tensor>,
    pub served: u64,
}

impl ShardServer {
    pub fn new(
        engine: Rc<RefCell<Engine>>,
        layers: (usize, usize),
        is_first: bool,
        is_last: bool,
        params: Vec<Tensor>,
    ) -> ShardServer {
        ShardServer {
            engine,
            layers,
            is_first,
            is_last,
            params,
            served: 0,
        }
    }

    /// Run this shard's portion: (optional embed) → layers → (optional head).
    pub fn forward(&mut self, req: &ShardRequest) -> Result<Tensor> {
        let mut engine = self.engine.borrow_mut();
        let cfg = engine.manifest.config.clone();
        let n = self.params.len();
        let mut hidden = if self.is_first {
            anyhow::ensure!(
                req.tokens.len() == cfg.seq_len,
                "expected {} tokens, got {}",
                cfg.seq_len,
                req.tokens.len()
            );
            let tok = Tensor::from_i32(&[1, cfg.seq_len], &req.tokens);
            engine
                .run(
                    "embed",
                    &[tok, self.params[0].clone(), self.params[1].clone()],
                )?
                .into_iter()
                .next()
                .context("embed output")?
        } else {
            req.hidden.clone().context("missing hidden activation")?
        };
        for layer in self.layers.0..self.layers.1 {
            let (a, b) = engine.manifest.layer_param_range(layer);
            let mut inputs = vec![hidden];
            inputs.extend(self.params[a..b].iter().cloned());
            hidden = engine
                .run("layer_fwd", &inputs)?
                .into_iter()
                .next()
                .context("layer output")?;
        }
        if self.is_last {
            hidden = engine
                .run(
                    "logits",
                    &[
                        hidden,
                        self.params[n - 3].clone(),
                        self.params[n - 2].clone(),
                        self.params[n - 1].clone(),
                    ],
                )?
                .into_iter()
                .next()
                .context("logits output")?;
        }
        self.served += 1;
        Ok(hidden)
    }

    /// Hot-swap parameters (model sync scenario).
    pub fn swap_params(&mut self, params: Vec<Tensor>) {
        self.params = params;
    }

    /// Turn this server into a registered [`Service`] for
    /// [`LatticaNode::register_service`]. The returned shared handle
    /// keeps the server reachable for hot-swapping parameters and
    /// inspecting the `served` counter while the service runs.
    pub fn into_service(self) -> (Service, Rc<RefCell<ShardServer>>) {
        let server = Rc::new(RefCell::new(self));
        let h = server.clone();
        let svc = Service::new(SHARD_SERVICE)
            .unary("forward", move |_node, _net, _ctx, payload| {
                match ShardRequest::decode(&payload).and_then(|r| h.borrow_mut().forward(&r)) {
                    Ok(out) => Outcome::reply(out.encode()),
                    Err(e) => Outcome::fail(Status::Error, e.to_string()),
                }
            })
            .unary("health", |_node, _net, _ctx, _payload| Outcome::reply(&b"ok"[..]));
        (svc, server)
    }
}

/// Per-hop deadline before a stage attempt fails over to the next
/// replica.
const STAGE_ATTEMPT_TIMEOUT: Time = 2 * SECOND;
/// Overall budget for one hop (all replica attempts included).
const STAGE_DEADLINE: Time = 30 * SECOND;

/// Client-side pipeline: ordered shard stages, each served by a [`Stub`]
/// over its replica set. A failed hop (timeout, unreachable replica,
/// `Unavailable`) fails over to the next replica inside the stub; the
/// pipeline only sees hops that finally succeeded or exhausted every
/// replica.
pub struct PipelineClient {
    /// stages[i] = replica PeerIds for shard i, in preference order.
    pub stages: Vec<Vec<PeerId>>,
    /// One stub per stage (targets = that stage's replicas).
    stubs: Vec<Stub>,
    pub next_request_id: u64,
    /// In-flight hops: (stage, stub op id) → run state.
    runs: HashMap<(usize, u64), RunState>,
    pub completed: Vec<(u64, Tensor, Time)>, // (request, logits, started_at)
    pub failed: Vec<(u64, String)>,
}

struct RunState {
    request_id: u64,
    tokens: Vec<i32>,
    hidden: Option<Tensor>,
    started_at: Time,
}

impl PipelineClient {
    pub fn new(stages: Vec<Vec<PeerId>>) -> PipelineClient {
        let stubs = stages
            .iter()
            .map(|replicas| {
                Stub::new(SHARD_SERVICE, replicas.clone()).with_options(CallOptions {
                    deadline: STAGE_DEADLINE,
                    attempt_timeout: Some(STAGE_ATTEMPT_TIMEOUT),
                    retry: RetryPolicy {
                        // Enough attempts to visit every replica at least
                        // once, plus one revisit.
                        max_attempts: replicas.len().max(1) as u32 + 1,
                        base_backoff: 25 * MILLI,
                        max_backoff: 500 * MILLI,
                        jitter: 0.5,
                        // One replica serving errors (stale params after a
                        // bad hot-swap, local corruption) must not fail
                        // the request while a healthy sibling exists.
                        retry_on_error: true,
                    },
                    ..CallOptions::default()
                })
            })
            .collect();
        PipelineClient {
            stages,
            stubs,
            next_request_id: 1,
            runs: HashMap::new(),
            completed: Vec::new(),
            failed: Vec::new(),
        }
    }

    /// Per-stage stub stats (failovers, retries…), for tests and reports.
    pub fn stage_stats(&self, stage: usize) -> crate::metrics::StubStats {
        self.stubs[stage].stats
    }

    /// Start a pipeline run over `tokens`; returns the request id.
    pub fn infer(&mut self, node: &mut LatticaNode, net: &mut Net, tokens: Vec<i32>) -> Result<u64> {
        anyhow::ensure!(!self.stages.is_empty(), "pipeline has no stages");
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        let run = RunState {
            request_id,
            tokens,
            hidden: None,
            started_at: net.now(),
        };
        self.dispatch(node, net, 0, run);
        Ok(request_id)
    }

    fn dispatch(&mut self, node: &mut LatticaNode, net: &mut Net, stage: usize, run: RunState) {
        let req = ShardRequest {
            request_id: run.request_id,
            tokens: if stage == 0 { run.tokens.clone() } else { vec![] },
            hidden: run.hidden.clone(),
        };
        let op = self.stubs[stage].call(node, net, "forward", req.encode());
        self.runs.insert((stage, op), run);
    }

    /// Feed RPC events; returns true if the event was consumed.
    pub fn on_rpc_event(&mut self, node: &mut LatticaNode, net: &mut Net, ev: &RpcEvent) -> bool {
        let mut consumed = false;
        for stub in &mut self.stubs {
            if stub.on_rpc_event(node, net, ev) {
                consumed = true;
                break;
            }
        }
        self.advance(node, net);
        consumed
    }

    /// Drive stub timers (retry backoff, per-hop deadlines). Call once
    /// per event-loop iteration.
    pub fn tick(&mut self, node: &mut LatticaNode, net: &mut Net) {
        for stub in &mut self.stubs {
            stub.tick(node, net);
        }
        self.advance(node, net);
    }

    /// Collect finished hops and dispatch the next stage.
    fn advance(&mut self, node: &mut LatticaNode, net: &mut Net) {
        for stage in 0..self.stubs.len() {
            while let Some(done) = self.stubs[stage].poll_done() {
                let Some(mut run) = self.runs.remove(&(stage, done.op)) else {
                    continue;
                };
                if done.status != Status::Ok {
                    self.failed.push((
                        run.request_id,
                        format!(
                            "stage {stage}: all replicas failed ({:?}: {})",
                            done.status, done.detail
                        ),
                    ));
                    continue;
                }
                let Ok(t) = Tensor::decode(&done.payload) else {
                    self.failed.push((run.request_id, "bad tensor".into()));
                    continue;
                };
                if stage + 1 == self.stages.len() {
                    self.completed.push((run.request_id, t, run.started_at));
                } else {
                    run.hidden = Some(t);
                    let next = RunState {
                        tokens: Vec::new(),
                        ..run
                    };
                    self.dispatch(node, net, stage + 1, next);
                }
            }
        }
    }

    pub fn in_flight(&self) -> usize {
        self.runs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_request_roundtrip() {
        let r = ShardRequest {
            request_id: 9,
            tokens: vec![1, 2, 3],
            hidden: None,
        };
        assert_eq!(ShardRequest::decode(&r.encode()).unwrap(), r);
        let r = ShardRequest {
            request_id: 10,
            tokens: vec![],
            hidden: Some(Tensor::from_f32(&[1, 2, 2], &[1.0, 2.0, 3.0, 4.0])),
        };
        assert_eq!(ShardRequest::decode(&r.encode()).unwrap(), r);
    }

    /// A hostile token count must be rejected before any allocation sized
    /// from it — a 10-byte frame claiming 2^60 tokens previously asked the
    /// allocator for 2^62 bytes up front.
    #[test]
    fn shard_request_hostile_token_count() {
        let mut buf = Vec::new();
        varint::put_uvarint(&mut buf, 1); // request_id
        varint::put_uvarint(&mut buf, 1u64 << 60); // claimed token count
        assert!(ShardRequest::decode(&buf).is_err());

        // Just over the cap is also rejected, even with the count itself
        // well-formed.
        let mut buf = Vec::new();
        varint::put_uvarint(&mut buf, 1);
        varint::put_uvarint(&mut buf, (MAX_TOKENS + 1) as u64);
        assert!(ShardRequest::decode(&buf).is_err());

        // At the cap but truncated: errors on the missing bytes without
        // over-allocating (capacity is bounded by remaining input).
        let mut buf = Vec::new();
        varint::put_uvarint(&mut buf, 1);
        varint::put_uvarint(&mut buf, MAX_TOKENS as u64);
        buf.push(7);
        assert!(ShardRequest::decode(&buf).is_err());
    }
}
