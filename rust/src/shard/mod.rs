//! Sharded inference: shard servers hosting layer ranges, and a
//! shard-aware pipeline client with DHT-based failover (Fig. 1(4)).
//!
//! A request enters at shard 0 (embed + first layers); activations hop
//! between shards as RPC tensor payloads; the last shard applies the
//! logits head and the next-token distribution returns to the caller.
//! Shards are replicated: the client stub retries a failed hop on an
//! alternate replica resolved from its provider table.

use crate::identity::PeerId;
use crate::netsim::Net;
use crate::node::{App, LatticaNode, NodeEvent};
use crate::protocols::Ctx;
use crate::rpc::{ReplyHandle, RpcEvent, Status};
use crate::runtime::{Engine, Tensor};
use crate::util::varint;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::rc::Rc;

pub const SHARD_SERVICE: &str = "shard";

/// Request payload for the `forward` method.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardRequest {
    /// Request id assigned by the entry client (for tracing).
    pub request_id: u64,
    /// Tokens (only shard 0 uses this) or empty.
    pub tokens: Vec<i32>,
    /// Hidden activation (shards > 0), empty for shard 0.
    pub hidden: Option<Tensor>,
}

impl ShardRequest {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::put_uvarint(&mut out, self.request_id);
        varint::put_uvarint(&mut out, self.tokens.len() as u64);
        for &t in &self.tokens {
            varint::put_uvarint(&mut out, t as u64);
        }
        match &self.hidden {
            Some(h) => {
                out.push(1);
                out.extend_from_slice(&h.encode());
            }
            None => out.push(0),
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ShardRequest> {
        let mut r = varint::Reader::new(buf);
        let request_id = r.uvarint()?;
        let n = r.uvarint()? as usize;
        let mut tokens = Vec::with_capacity(n);
        for _ in 0..n {
            tokens.push(r.uvarint()? as i32);
        }
        let flag = r.take(1)?[0];
        let hidden = if flag == 1 {
            Some(Tensor::decode(&buf[r.pos..])?)
        } else {
            None
        };
        Ok(ShardRequest {
            request_id,
            tokens,
            hidden,
        })
    }
}

/// A shard server app: owns a layer range and (for the edge shards) the
/// embedding/logits heads. Parameters are the node's local copy (fetched
/// via Bitswap in the full pipeline).
pub struct ShardServer {
    pub engine: Rc<RefCell<Engine>>,
    /// Layer range [start, end).
    pub layers: (usize, usize),
    pub is_first: bool,
    pub is_last: bool,
    /// Full parameter list (only the owned slices are used).
    pub params: Vec<Tensor>,
    pub served: u64,
}

impl ShardServer {
    pub fn new(
        engine: Rc<RefCell<Engine>>,
        layers: (usize, usize),
        is_first: bool,
        is_last: bool,
        params: Vec<Tensor>,
    ) -> ShardServer {
        ShardServer {
            engine,
            layers,
            is_first,
            is_last,
            params,
            served: 0,
        }
    }

    /// Run this shard's portion: (optional embed) → layers → (optional head).
    pub fn forward(&mut self, req: &ShardRequest) -> Result<Tensor> {
        let mut engine = self.engine.borrow_mut();
        let cfg = engine.manifest.config.clone();
        let n = self.params.len();
        let mut hidden = if self.is_first {
            anyhow::ensure!(
                req.tokens.len() == cfg.seq_len,
                "expected {} tokens, got {}",
                cfg.seq_len,
                req.tokens.len()
            );
            let tok = Tensor::from_i32(&[1, cfg.seq_len], &req.tokens);
            engine
                .run(
                    "embed",
                    &[tok, self.params[0].clone(), self.params[1].clone()],
                )?
                .into_iter()
                .next()
                .context("embed output")?
        } else {
            req.hidden.clone().context("missing hidden activation")?
        };
        for layer in self.layers.0..self.layers.1 {
            let (a, b) = engine.manifest.layer_param_range(layer);
            let mut inputs = vec![hidden];
            inputs.extend(self.params[a..b].iter().cloned());
            hidden = engine
                .run("layer_fwd", &inputs)?
                .into_iter()
                .next()
                .context("layer output")?;
        }
        if self.is_last {
            hidden = engine
                .run(
                    "logits",
                    &[
                        hidden,
                        self.params[n - 3].clone(),
                        self.params[n - 2].clone(),
                        self.params[n - 1].clone(),
                    ],
                )?
                .into_iter()
                .next()
                .context("logits output")?;
        }
        self.served += 1;
        Ok(hidden)
    }

    /// Hot-swap parameters (model sync scenario).
    pub fn swap_params(&mut self, params: Vec<Tensor>) {
        self.params = params;
    }
}

impl App for ShardServer {
    fn handle(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        ev: NodeEvent,
    ) -> Option<NodeEvent> {
        match ev {
            NodeEvent::Rpc(RpcEvent::Request {
                service,
                method,
                payload,
                reply,
                ..
            }) if service == SHARD_SERVICE => {
                let mut ctx = Ctx::new(&mut node.swarm, net);
                match method.as_str() {
                    "forward" => match ShardRequest::decode(&payload).and_then(|r| self.forward(&r)) {
                        Ok(out) => {
                            let _ = node.rpc.respond(&mut ctx, reply, Status::Ok, out.encode());
                        }
                        Err(e) => {
                            let _ = node.rpc.respond(
                                &mut ctx,
                                reply,
                                Status::Error,
                                e.to_string().as_bytes(),
                            );
                        }
                    },
                    "health" => {
                        let _ = node.rpc.respond(&mut ctx, reply, Status::Ok, b"ok");
                    }
                    _ => {
                        let _ = node.rpc.respond(&mut ctx, reply, Status::NotFound, b"");
                    }
                }
                None
            }
            other => Some(other),
        }
    }
}

/// Reply handle re-export for apps.
pub type Reply = ReplyHandle;

/// Client-side pipeline: ordered shard stages, each with replica peers.
/// Retries a failed hop on the next replica (the shard-aware stub).
pub struct PipelineClient {
    /// stages[i] = replica PeerIds for shard i, in preference order.
    pub stages: Vec<Vec<PeerId>>,
    pub next_request_id: u64,
    /// In-flight pipeline runs: call_id → run state.
    runs: std::collections::HashMap<u64, RunState>,
    pub completed: Vec<(u64, Tensor, crate::netsim::Time)>, // (request, logits, started_at)
    pub failed: Vec<(u64, String)>,
}

struct RunState {
    request_id: u64,
    stage: usize,
    replica: usize,
    tokens: Vec<i32>,
    hidden: Option<Tensor>,
    started_at: crate::netsim::Time,
}

impl PipelineClient {
    pub fn new(stages: Vec<Vec<PeerId>>) -> PipelineClient {
        PipelineClient {
            stages,
            next_request_id: 1,
            runs: std::collections::HashMap::new(),
            completed: Vec::new(),
            failed: Vec::new(),
        }
    }

    /// Start a pipeline run over `tokens`; returns the request id.
    pub fn infer(&mut self, node: &mut LatticaNode, net: &mut Net, tokens: Vec<i32>) -> Result<u64> {
        let request_id = self.next_request_id;
        self.next_request_id += 1;
        let run = RunState {
            request_id,
            stage: 0,
            replica: 0,
            tokens,
            hidden: None,
            started_at: net.now(),
        };
        self.dispatch(node, net, run)?;
        Ok(request_id)
    }

    fn dispatch(&mut self, node: &mut LatticaNode, net: &mut Net, run: RunState) -> Result<()> {
        let replicas = &self.stages[run.stage];
        anyhow::ensure!(
            run.replica < replicas.len(),
            "request {}: all replicas of stage {} failed",
            run.request_id,
            run.stage
        );
        let peer = replicas[run.replica];
        let req = ShardRequest {
            request_id: run.request_id,
            tokens: if run.stage == 0 { run.tokens.clone() } else { vec![] },
            hidden: run.hidden.clone(),
        };
        let mut ctx = Ctx::new(&mut node.swarm, net);
        let call_id = node
            .rpc
            .call(&mut ctx, &peer, SHARD_SERVICE, "forward", req.encode())?;
        self.runs.insert(call_id, run);
        Ok(())
    }

    /// Feed RPC events; returns true if the event was consumed.
    pub fn on_rpc_event(&mut self, node: &mut LatticaNode, net: &mut Net, ev: &RpcEvent) -> bool {
        match ev {
            RpcEvent::Response {
                call_id,
                status,
                payload,
                ..
            } => {
                let Some(mut run) = self.runs.remove(call_id) else {
                    return false;
                };
                if *status != Status::Ok {
                    // Failover: try the next replica of this stage.
                    run.replica += 1;
                    let rid = run.request_id;
                    if let Err(e) = self.dispatch(node, net, run) {
                        // Exhausted replicas.
                        self.failed.push((rid, e.to_string()));
                    }
                    return true;
                }
                let Ok(t) = Tensor::decode(payload) else {
                    self.failed.push((run.request_id, "bad tensor".into()));
                    return true;
                };
                if run.stage + 1 == self.stages.len() {
                    self.completed.push((run.request_id, t, run.started_at));
                } else {
                    run.stage += 1;
                    run.replica = 0;
                    run.hidden = Some(t);
                    let rid = run.request_id;
                    if let Err(e) = self.dispatch(node, net, run) {
                        self.failed.push((rid, e.to_string()));
                    }
                }
                true
            }
            RpcEvent::CallFailed { call_id, .. } => {
                let Some(mut run) = self.runs.remove(call_id) else {
                    return false;
                };
                run.replica += 1;
                let rid = run.request_id;
                if let Err(e) = self.dispatch(node, net, run) {
                    self.failed.push((rid, e.to_string()));
                }
                true
            }
            _ => false,
        }
    }

    pub fn in_flight(&self) -> usize {
        self.runs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_request_roundtrip() {
        let r = ShardRequest {
            request_id: 9,
            tokens: vec![1, 2, 3],
            hidden: None,
        };
        assert_eq!(ShardRequest::decode(&r.encode()).unwrap(), r);
        let r = ShardRequest {
            request_id: 10,
            tokens: vec![],
            hidden: Some(Tensor::from_f32(&[1, 2, 2], &[1.0, 2.0, 3.0, 4.0])),
        };
        assert_eq!(ShardRequest::decode(&r.encode()).unwrap(), r);
    }
}
