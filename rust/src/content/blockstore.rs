//! In-memory verified blockstore with size accounting and LRU-ish pruning.

use super::cid::Cid;
use anyhow::Result;
use std::collections::HashMap;
use std::rc::Rc;

/// Block storage keyed by CID. Every `put` verifies the hash; blocks are
/// reference-counted (`Rc`) so Bitswap can serve them without copying.
pub struct Blockstore {
    blocks: HashMap<Cid, Rc<Vec<u8>>>,
    total_bytes: usize,
    /// Optional cap; inserting beyond it evicts in insertion order.
    pub capacity_bytes: Option<usize>,
    insertion_order: Vec<Cid>,
}

impl Default for Blockstore {
    fn default() -> Self {
        Self::new()
    }
}

impl Blockstore {
    pub fn new() -> Blockstore {
        Blockstore {
            blocks: HashMap::new(),
            total_bytes: 0,
            capacity_bytes: None,
            insertion_order: Vec::new(),
        }
    }

    /// Store a block; returns its CID.
    pub fn put(&mut self, data: Vec<u8>) -> Cid {
        let cid = Cid::of(&data);
        self.put_verified(cid, data).expect("hash just computed");
        cid
    }

    /// Store a block claimed to have `cid`; fails if the hash mismatches.
    pub fn put_verified(&mut self, cid: Cid, data: Vec<u8>) -> Result<()> {
        anyhow::ensure!(cid.verify(&data), "block does not match CID {cid}");
        if self.blocks.contains_key(&cid) {
            return Ok(());
        }
        self.total_bytes += data.len();
        self.blocks.insert(cid, Rc::new(data));
        self.insertion_order.push(cid);
        if let Some(cap) = self.capacity_bytes {
            while self.total_bytes > cap && self.insertion_order.len() > 1 {
                let victim = self.insertion_order.remove(0);
                if victim == cid {
                    self.insertion_order.push(victim);
                    continue;
                }
                if let Some(b) = self.blocks.remove(&victim) {
                    self.total_bytes -= b.len();
                }
            }
        }
        Ok(())
    }

    pub fn get(&self, cid: &Cid) -> Option<Rc<Vec<u8>>> {
        self.blocks.get(cid).cloned()
    }

    pub fn has(&self, cid: &Cid) -> bool {
        self.blocks.contains_key(cid)
    }

    pub fn remove(&mut self, cid: &Cid) {
        if let Some(b) = self.blocks.remove(cid) {
            self.total_bytes -= b.len();
            self.insertion_order.retain(|c| c != cid);
        }
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn total_bytes(&self) -> usize {
        self.total_bytes
    }

    pub fn cids(&self) -> impl Iterator<Item = &Cid> {
        self.blocks.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut bs = Blockstore::new();
        let cid = bs.put(b"hello world".to_vec());
        assert!(bs.has(&cid));
        assert_eq!(&**bs.get(&cid).unwrap(), b"hello world");
        assert_eq!(bs.len(), 1);
        assert_eq!(bs.total_bytes(), 11);
    }

    #[test]
    fn duplicate_put_idempotent() {
        let mut bs = Blockstore::new();
        let c1 = bs.put(b"same".to_vec());
        let c2 = bs.put(b"same".to_vec());
        assert_eq!(c1, c2);
        assert_eq!(bs.len(), 1);
        assert_eq!(bs.total_bytes(), 4);
    }

    #[test]
    fn verification_rejects_forgery() {
        let mut bs = Blockstore::new();
        let cid = Cid::of(b"real");
        assert!(bs.put_verified(cid, b"fake".to_vec()).is_err());
        assert!(!bs.has(&cid));
        assert!(bs.put_verified(cid, b"real".to_vec()).is_ok());
    }

    #[test]
    fn capacity_evicts_oldest() {
        let mut bs = Blockstore::new();
        bs.capacity_bytes = Some(25);
        let c1 = bs.put(vec![1u8; 10]);
        let c2 = bs.put(vec![2u8; 10]);
        let c3 = bs.put(vec![3u8; 10]);
        assert!(!bs.has(&c1), "oldest evicted");
        assert!(bs.has(&c2) && bs.has(&c3));
        assert!(bs.total_bytes() <= 25);
    }

    #[test]
    fn remove_updates_accounting() {
        let mut bs = Blockstore::new();
        let cid = bs.put(vec![0u8; 100]);
        bs.remove(&cid);
        assert!(!bs.has(&cid));
        assert_eq!(bs.total_bytes(), 0);
        assert!(bs.is_empty());
    }
}
