//! In-memory verified blockstore with size accounting and LRU-ish pruning.

use super::cid::Cid;
use crate::util::buf::Buf;
use anyhow::Result;
use std::collections::HashMap;

/// Write-path counters — the duplicate-suppression evidence used by the
/// re-stripe regression tests (a late block from a slow provider must not
/// cause a second store write).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockstoreStats {
    /// Blocks newly written.
    pub stores: u64,
    /// `put` calls that found the block already present (no write).
    pub duplicate_puts: u64,
}

/// Block storage keyed by CID. Every `put` verifies the hash; blocks are
/// stored as reference-counted [`Buf`]s, so Bitswap serves them to N peers
/// with refcount bumps instead of N copies, and a block received off the
/// wire is retained as a slice of the receive buffer.
pub struct Blockstore {
    blocks: HashMap<Cid, Buf>,
    total_bytes: usize,
    /// Optional cap; inserting beyond it evicts in insertion order.
    pub capacity_bytes: Option<usize>,
    insertion_order: Vec<Cid>,
    pub stats: BlockstoreStats,
}

impl Default for Blockstore {
    fn default() -> Self {
        Self::new()
    }
}

impl Blockstore {
    pub fn new() -> Blockstore {
        Blockstore {
            blocks: HashMap::new(),
            total_bytes: 0,
            capacity_bytes: None,
            insertion_order: Vec::new(),
            stats: BlockstoreStats::default(),
        }
    }

    /// Store a block; returns its CID.
    pub fn put(&mut self, data: impl Into<Buf>) -> Cid {
        let data = data.into();
        let cid = Cid::of(&data);
        self.put_verified(cid, data).expect("hash just computed");
        cid
    }

    /// Store a block claimed to have `cid`; fails if the hash mismatches.
    pub fn put_verified(&mut self, cid: Cid, data: impl Into<Buf>) -> Result<()> {
        let data = data.into();
        anyhow::ensure!(cid.verify(&data), "block does not match CID {cid}");
        if self.blocks.contains_key(&cid) {
            self.stats.duplicate_puts += 1;
            return Ok(());
        }
        self.stats.stores += 1;
        self.total_bytes += data.len();
        self.blocks.insert(cid, data);
        self.insertion_order.push(cid);
        if let Some(cap) = self.capacity_bytes {
            while self.total_bytes > cap && self.insertion_order.len() > 1 {
                let victim = self.insertion_order.remove(0);
                if victim == cid {
                    self.insertion_order.push(victim);
                    continue;
                }
                if let Some(b) = self.blocks.remove(&victim) {
                    self.total_bytes -= b.len();
                }
            }
        }
        Ok(())
    }

    /// Fetch a block (reference-count bump, no copy).
    pub fn get(&self, cid: &Cid) -> Option<Buf> {
        self.blocks.get(cid).cloned()
    }

    pub fn has(&self, cid: &Cid) -> bool {
        self.blocks.contains_key(cid)
    }

    pub fn remove(&mut self, cid: &Cid) {
        if let Some(b) = self.blocks.remove(cid) {
            self.total_bytes -= b.len();
            self.insertion_order.retain(|c| c != cid);
        }
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn total_bytes(&self) -> usize {
        self.total_bytes
    }

    pub fn cids(&self) -> impl Iterator<Item = &Cid> {
        self.blocks.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut bs = Blockstore::new();
        let cid = bs.put(b"hello world".to_vec());
        assert!(bs.has(&cid));
        assert_eq!(bs.get(&cid).unwrap(), b"hello world");
        assert_eq!(bs.len(), 1);
        assert_eq!(bs.total_bytes(), 11);
    }

    #[test]
    fn get_is_refcounted_not_copied() {
        let mut bs = Blockstore::new();
        let cid = bs.put(vec![9u8; 1000]);
        let a = bs.get(&cid).unwrap();
        let b = bs.get(&cid).unwrap();
        assert_eq!(a.ref_count(), 3, "store + two readers share one allocation");
        assert_eq!(a, b);
    }

    #[test]
    fn duplicate_put_idempotent() {
        let mut bs = Blockstore::new();
        let c1 = bs.put(b"same".to_vec());
        let c2 = bs.put(b"same".to_vec());
        assert_eq!(c1, c2);
        assert_eq!(bs.len(), 1);
        assert_eq!(bs.total_bytes(), 4);
        assert_eq!(bs.stats.stores, 1);
        assert_eq!(bs.stats.duplicate_puts, 1);
    }

    #[test]
    fn verification_rejects_forgery() {
        let mut bs = Blockstore::new();
        let cid = Cid::of(b"real");
        assert!(bs.put_verified(cid, b"fake".to_vec()).is_err());
        assert!(!bs.has(&cid));
        assert!(bs.put_verified(cid, b"real".to_vec()).is_ok());
    }

    #[test]
    fn capacity_evicts_oldest() {
        let mut bs = Blockstore::new();
        bs.capacity_bytes = Some(25);
        let c1 = bs.put(vec![1u8; 10]);
        let c2 = bs.put(vec![2u8; 10]);
        let c3 = bs.put(vec![3u8; 10]);
        assert!(!bs.has(&c1), "oldest evicted");
        assert!(bs.has(&c2) && bs.has(&c3));
        assert!(bs.total_bytes() <= 25);
    }

    #[test]
    fn remove_updates_accounting() {
        let mut bs = Blockstore::new();
        let cid = bs.put(vec![0u8; 100]);
        bs.remove(&cid);
        assert!(!bs.has(&cid));
        assert_eq!(bs.total_bytes(), 0);
        assert!(bs.is_empty());
    }
}
