//! Content-addressed storage: CIDs, chunking, the blockstore and manifests.
//!
//! Data blocks are named by the SHA-256 multihash of their bytes (§2
//! "Content-Addressed Data Synchronization"). Large artifacts (model
//! checkpoints, static assets) are chunked; a [`manifest::DagManifest`]
//! lists the chunk CIDs and is itself a block, so one root CID names the
//! whole artifact and every transfer is verifiable.

pub mod cid;
pub mod chunker;
pub mod blockstore;
pub mod manifest;

pub use blockstore::{Blockstore, BlockstoreStats};
pub use cid::Cid;
pub use chunker::{chunk_cdc, chunk_fixed, chunk_rolling, CdcParams, CDC_CHECKPOINT, DEFAULT_CHUNK_SIZE};
pub use manifest::{Chunking, DagManifest, DeltaManifest};
