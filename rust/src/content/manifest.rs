//! DAG manifests: one root CID naming a chunked artifact.
//!
//! The manifest block lists the chunk CIDs (in order) plus metadata; its
//! own CID is the artifact's root. Fetching = get manifest block → get
//! chunks (any provider, any order) → verify each against its CID →
//! reassemble. A tampered chunk cannot slip through because the chunk CID
//! is bound by the manifest, which is bound by the root.

use super::blockstore::Blockstore;
use super::cid::Cid;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::{Context, Result};

#[derive(Clone, Debug, Default, PartialEq)]
pub struct DagManifest {
    /// Human-readable label ("model/ckpt-120", "asset/video.bin").
    pub name: String,
    /// Application version counter (model checkpoint step, asset rev).
    pub version: u64,
    /// Total payload size in bytes.
    pub total_size: u64,
    /// Chunk CIDs in order.
    pub chunks: Vec<Cid>,
}

impl Message for DagManifest {
    fn encode_to(&self, w: &mut PbWriter) {
        w.string(1, &self.name);
        w.uint(2, self.version);
        w.uint(3, self.total_size);
        for c in &self.chunks {
            w.bytes(4, c.as_bytes());
        }
    }

    fn decode(buf: &[u8]) -> Result<DagManifest> {
        let mut m = DagManifest::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.name = f.as_string()?,
                2 => m.version = f.as_u64(),
                3 => m.total_size = f.as_u64(),
                4 => m.chunks.push(Cid::from_bytes(f.as_bytes()?)?),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

impl DagManifest {
    /// Chunk `data`, store chunks + manifest, return (root CID, manifest).
    pub fn publish(
        store: &mut Blockstore,
        name: &str,
        version: u64,
        data: &[u8],
        chunk_size: usize,
    ) -> (Cid, DagManifest) {
        let chunks: Vec<Cid> = super::chunker::chunk_fixed(data, chunk_size)
            .into_iter()
            .map(|c| store.put(c.to_vec()))
            .collect();
        let m = DagManifest {
            name: name.to_string(),
            version,
            total_size: data.len() as u64,
            chunks,
        };
        let root = store.put(m.encode());
        (root, m)
    }

    /// Load a manifest block from the store by root CID.
    pub fn load(store: &Blockstore, root: &Cid) -> Result<DagManifest> {
        let block = store.get(root).context("manifest block missing")?;
        DagManifest::decode(&block)
    }

    /// Whether every chunk is locally present.
    pub fn is_complete(&self, store: &Blockstore) -> bool {
        self.chunks.iter().all(|c| store.has(c))
    }

    /// CIDs still missing locally.
    pub fn missing<'a>(&'a self, store: &Blockstore) -> Vec<Cid> {
        self.chunks.iter().filter(|c| !store.has(c)).copied().collect()
    }

    /// Reassemble the payload (fails if chunks are missing or sizes lie).
    pub fn assemble(&self, store: &Blockstore) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.total_size as usize);
        for c in &self.chunks {
            let b = store
                .get(c)
                .with_context(|| format!("missing chunk {c}"))?;
            out.extend_from_slice(&b);
        }
        anyhow::ensure!(
            out.len() as u64 == self.total_size,
            "assembled size {} != declared {}",
            out.len(),
            self.total_size
        );
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn publish_fetch_roundtrip() {
        let mut store = Blockstore::new();
        let mut rng = Rng::new(4);
        let data = rng.gen_bytes(700_000);
        let (root, m) = DagManifest::publish(&mut store, "asset/x", 3, &data, 256 * 1024);
        assert_eq!(m.chunks.len(), 3);
        assert!(m.is_complete(&store));
        let loaded = DagManifest::load(&store, &root).unwrap();
        assert_eq!(loaded, m);
        assert_eq!(loaded.assemble(&store).unwrap(), data);
    }

    #[test]
    fn missing_chunks_reported() {
        let mut store = Blockstore::new();
        // Distinct chunk contents (identical chunks would share one CID).
        let mut rng = Rng::new(5);
        let data = rng.gen_bytes(100_000);
        let (root, m) = DagManifest::publish(&mut store, "a", 1, &data, 30_000);
        store.remove(&m.chunks[1]);
        let loaded = DagManifest::load(&store, &root).unwrap();
        assert!(!loaded.is_complete(&store));
        assert_eq!(loaded.missing(&store), vec![m.chunks[1]]);
        assert!(loaded.assemble(&store).is_err());
    }

    #[test]
    fn root_binds_everything() {
        let mut s1 = Blockstore::new();
        let (root1, _) = DagManifest::publish(&mut s1, "a", 1, &[1, 2, 3], 2);
        let mut s2 = Blockstore::new();
        let (root2, _) = DagManifest::publish(&mut s2, "a", 1, &[1, 2, 4], 2);
        assert_ne!(root1, root2, "different payloads → different roots");
        let mut s3 = Blockstore::new();
        let (root3, _) = DagManifest::publish(&mut s3, "a", 2, &[1, 2, 3], 2);
        assert_ne!(root1, root3, "version is part of the root");
    }

    #[test]
    fn empty_payload() {
        let mut store = Blockstore::new();
        let (root, m) = DagManifest::publish(&mut store, "empty", 1, &[], 1024);
        assert!(m.chunks.is_empty());
        let loaded = DagManifest::load(&store, &root).unwrap();
        assert_eq!(loaded.assemble(&store).unwrap(), Vec::<u8>::new());
    }
}
