//! DAG manifests: one root CID naming a chunked artifact.
//!
//! The manifest block lists the chunk CIDs (in order) plus metadata; its
//! own CID is the artifact's root. Fetching = get manifest block → get
//! chunks (any provider, any order) → verify each against its CID →
//! reassemble. A tampered chunk cannot slip through because the chunk CID
//! is bound by the manifest, which is bound by the root.

use super::blockstore::Blockstore;
use super::chunker::CdcParams;
use super::cid::Cid;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::{Context, Result};

/// How a blob is split into blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Chunking {
    /// Fixed-size chunks (fast; no cross-version reuse under shifts).
    Fixed(usize),
    /// FastCDC content-defined chunks (stable boundaries ⇒ checkpoint
    /// version v+1 reuses the CIDs of unchanged chunks from v).
    Cdc(CdcParams),
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct DagManifest {
    /// Human-readable label ("model/ckpt-120", "asset/video.bin").
    pub name: String,
    /// Application version counter (model checkpoint step, asset rev).
    pub version: u64,
    /// Total payload size in bytes.
    pub total_size: u64,
    /// Chunk CIDs in order.
    pub chunks: Vec<Cid>,
}

impl Message for DagManifest {
    fn encode_to(&self, w: &mut PbWriter) {
        w.string(1, &self.name);
        w.uint(2, self.version);
        w.uint(3, self.total_size);
        for c in &self.chunks {
            w.bytes(4, c.as_bytes());
        }
    }

    fn decode(buf: &[u8]) -> Result<DagManifest> {
        let mut m = DagManifest::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.name = f.as_string()?,
                2 => m.version = f.as_u64(),
                3 => m.total_size = f.as_u64(),
                4 => m.chunks.push(Cid::from_bytes(f.as_bytes()?)?),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

impl DagManifest {
    /// Chunk `data`, store chunks + manifest, return (root CID, manifest).
    pub fn publish(
        store: &mut Blockstore,
        name: &str,
        version: u64,
        data: &[u8],
        chunk_size: usize,
    ) -> (Cid, DagManifest) {
        Self::publish_chunked(store, name, version, data, Chunking::Fixed(chunk_size))
    }

    /// [`DagManifest::publish`] with an explicit chunking policy.
    pub fn publish_chunked(
        store: &mut Blockstore,
        name: &str,
        version: u64,
        data: &[u8],
        chunking: Chunking,
    ) -> (Cid, DagManifest) {
        let parts = match chunking {
            Chunking::Fixed(size) => super::chunker::chunk_fixed(data, size),
            Chunking::Cdc(p) => super::chunker::chunk_cdc(data, p),
        };
        let chunks: Vec<Cid> = parts.into_iter().map(|c| store.put(c.to_vec())).collect();
        let m = DagManifest {
            name: name.to_string(),
            version,
            total_size: data.len() as u64,
            chunks,
        };
        let root = store.put(m.encode());
        (root, m)
    }

    /// Load a manifest block from the store by root CID.
    pub fn load(store: &Blockstore, root: &Cid) -> Result<DagManifest> {
        let block = store.get(root).context("manifest block missing")?;
        DagManifest::decode(&block)
    }

    /// Whether every chunk is locally present.
    pub fn is_complete(&self, store: &Blockstore) -> bool {
        self.chunks.iter().all(|c| store.has(c))
    }

    /// CIDs still missing locally.
    pub fn missing<'a>(&'a self, store: &Blockstore) -> Vec<Cid> {
        self.chunks.iter().filter(|c| !store.has(c)).copied().collect()
    }

    /// Reassemble the payload (fails if chunks are missing or sizes lie).
    pub fn assemble(&self, store: &Blockstore) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.total_size as usize);
        for c in &self.chunks {
            let b = store
                .get(c)
                .with_context(|| format!("missing chunk {c}"))?;
            out.extend_from_slice(&b);
        }
        anyhow::ensure!(
            out.len() as u64 == self.total_size,
            "assembled size {} != declared {}",
            out.len(),
            self.total_size
        );
        Ok(out)
    }
}

/// The difference between two versions of a chunked artifact.
///
/// Correctness never depends on this message: a subscriber holding the
/// base version's chunks computes the same "what to fetch" set from the
/// full manifest's [`DagManifest::missing`] (unchanged chunks share CIDs).
/// The delta manifest is the explicit contract — it names the base, the
/// added chunk set and its byte volume, so subscribers can decide delta vs
/// full up front and harnesses can verify how many bytes a sync *should*
/// move.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeltaManifest {
    pub name: String,
    pub version: u64,
    pub base_version: u64,
    /// Root CID of the base version's manifest.
    pub base_root: Cid,
    /// Root CID of this version's full manifest.
    pub root: Cid,
    pub total_size: u64,
    /// Chunk CIDs present in this version but not in the base (deduped,
    /// manifest order preserved).
    pub added: Vec<Cid>,
    /// Total bytes of the added chunks.
    pub added_bytes: u64,
}

impl Message for DeltaManifest {
    fn encode_to(&self, w: &mut PbWriter) {
        w.string(1, &self.name);
        w.uint(2, self.version);
        w.uint(3, self.base_version);
        w.bytes(4, self.base_root.as_bytes());
        w.bytes(5, self.root.as_bytes());
        w.uint(6, self.total_size);
        for c in &self.added {
            w.bytes_always(7, c.as_bytes());
        }
        w.uint(8, self.added_bytes);
    }

    fn decode(buf: &[u8]) -> Result<DeltaManifest> {
        let mut m = DeltaManifest::default();
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => m.name = f.as_string()?,
                2 => m.version = f.as_u64(),
                3 => m.base_version = f.as_u64(),
                4 => m.base_root = Cid::from_bytes(f.as_bytes()?)?,
                5 => m.root = Cid::from_bytes(f.as_bytes()?)?,
                6 => m.total_size = f.as_u64(),
                7 => m.added.push(Cid::from_bytes(f.as_bytes()?)?),
                8 => m.added_bytes = f.as_u64(),
                _ => {}
            }
            Ok(())
        })?;
        Ok(m)
    }
}

impl DeltaManifest {
    /// Diff `next` against `base`. Chunk sizes are read from `store`
    /// (which holds every chunk of `next`, having just published it).
    pub fn diff(
        base: &DagManifest,
        base_root: Cid,
        next: &DagManifest,
        next_root: Cid,
        store: &Blockstore,
    ) -> DeltaManifest {
        use std::collections::HashSet;
        let have: HashSet<Cid> = base.chunks.iter().copied().collect();
        let mut seen: HashSet<Cid> = HashSet::new();
        let mut added = Vec::new();
        let mut added_bytes = 0u64;
        for c in &next.chunks {
            if !have.contains(c) && seen.insert(*c) {
                added.push(*c);
                added_bytes += store.get(c).map(|b| b.len() as u64).unwrap_or(0);
            }
        }
        DeltaManifest {
            name: next.name.clone(),
            version: next.version,
            base_version: base.version,
            base_root,
            root: next_root,
            total_size: next.total_size,
            added,
            added_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn publish_fetch_roundtrip() {
        let mut store = Blockstore::new();
        let mut rng = Rng::new(4);
        let data = rng.gen_bytes(700_000);
        let (root, m) = DagManifest::publish(&mut store, "asset/x", 3, &data, 256 * 1024);
        assert_eq!(m.chunks.len(), 3);
        assert!(m.is_complete(&store));
        let loaded = DagManifest::load(&store, &root).unwrap();
        assert_eq!(loaded, m);
        assert_eq!(loaded.assemble(&store).unwrap(), data);
    }

    #[test]
    fn missing_chunks_reported() {
        let mut store = Blockstore::new();
        // Distinct chunk contents (identical chunks would share one CID).
        let mut rng = Rng::new(5);
        let data = rng.gen_bytes(100_000);
        let (root, m) = DagManifest::publish(&mut store, "a", 1, &data, 30_000);
        store.remove(&m.chunks[1]);
        let loaded = DagManifest::load(&store, &root).unwrap();
        assert!(!loaded.is_complete(&store));
        assert_eq!(loaded.missing(&store), vec![m.chunks[1]]);
        assert!(loaded.assemble(&store).is_err());
    }

    #[test]
    fn root_binds_everything() {
        let mut s1 = Blockstore::new();
        let (root1, _) = DagManifest::publish(&mut s1, "a", 1, &[1, 2, 3], 2);
        let mut s2 = Blockstore::new();
        let (root2, _) = DagManifest::publish(&mut s2, "a", 1, &[1, 2, 4], 2);
        assert_ne!(root1, root2, "different payloads → different roots");
        let mut s3 = Blockstore::new();
        let (root3, _) = DagManifest::publish(&mut s3, "a", 2, &[1, 2, 3], 2);
        assert_ne!(root1, root3, "version is part of the root");
    }

    #[test]
    fn cdc_publish_shares_chunks_across_versions() {
        let mut store = Blockstore::new();
        let mut rng = Rng::new(11);
        let v1 = rng.gen_bytes(600_000);
        let mut v2 = v1.clone();
        let patch = rng.gen_bytes(40_000);
        v2[100_000..140_000].copy_from_slice(&patch);
        let cdc = Chunking::Cdc(crate::content::CDC_CHECKPOINT);
        let (r1, m1) = DagManifest::publish_chunked(&mut store, "m", 1, &v1, cdc);
        let (r2, m2) = DagManifest::publish_chunked(&mut store, "m", 2, &v2, cdc);
        assert_ne!(r1, r2);
        let delta = DeltaManifest::diff(&m1, r1, &m2, r2, &store);
        assert_eq!(delta.base_root, r1);
        assert_eq!(delta.root, r2);
        assert!(!delta.added.is_empty());
        // A ~7% in-place edit must not dirty more than ~30% of the bytes.
        assert!(
            (delta.added_bytes as usize) < v2.len() * 3 / 10,
            "delta too large: {} of {}",
            delta.added_bytes,
            v2.len()
        );
        // The delta's added set is exactly what a base-holding store misses.
        let mut base_store = Blockstore::new();
        let (_, _) = DagManifest::publish_chunked(&mut base_store, "m", 1, &v1, cdc);
        let missing = m2.missing(&base_store);
        let missing_set: std::collections::HashSet<Cid> = missing.into_iter().collect();
        let added_set: std::collections::HashSet<Cid> = delta.added.iter().copied().collect();
        assert_eq!(missing_set, added_set);
        // Wire roundtrip.
        assert_eq!(DeltaManifest::decode(&delta.encode()).unwrap(), delta);
    }

    #[test]
    fn empty_payload() {
        let mut store = Blockstore::new();
        let (root, m) = DagManifest::publish(&mut store, "empty", 1, &[], 1024);
        assert!(m.chunks.is_empty());
        let loaded = DagManifest::load(&store, &root).unwrap();
        assert_eq!(loaded.assemble(&store).unwrap(), Vec::<u8>::new());
    }
}
