//! Content identifiers: SHA-256 multihash of the block bytes.

use crate::crypto::sha256::Sha256;
use crate::util::hex;
use anyhow::Result;
use std::fmt;

/// A content identifier (multihash code 0x12, length 32).
/// The `Default` value (all zeroes) is a sentinel that no real block
/// hashes to; decoders use it for "field absent".
#[derive(Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Cid(pub [u8; 32]);

impl Cid {
    /// Hash a block's bytes.
    pub fn of(data: &[u8]) -> Cid {
        let mut h = Sha256::new();
        h.update(data);
        Cid(h.finalize().into())
    }

    pub fn as_bytes(&self) -> &[u8; 32] {
        &self.0
    }

    /// The Kademlia key for provider records of this CID.
    pub fn to_key(&self) -> [u8; 32] {
        self.0
    }

    pub fn to_multihash(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(34);
        v.push(0x12);
        v.push(0x20);
        v.extend_from_slice(&self.0);
        v
    }

    pub fn from_bytes(b: &[u8]) -> Result<Cid> {
        anyhow::ensure!(b.len() == 32, "cid must be 32 bytes, got {}", b.len());
        let mut d = [0u8; 32];
        d.copy_from_slice(b);
        Ok(Cid(d))
    }

    /// Verify data against this CID.
    pub fn verify(&self, data: &[u8]) -> bool {
        Cid::of(data) == *self
    }
}

impl fmt::Debug for Cid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Cid({})", hex::encode_prefix(&self.0, 10))
    }
}

impl fmt::Display for Cid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", hex::encode_prefix(&self.0, 14))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct() {
        let a = Cid::of(b"hello");
        let b = Cid::of(b"hello");
        let c = Cid::of(b"hellp");
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn sha256_known_vector() {
        // sha256("abc")
        let cid = Cid::of(b"abc");
        assert_eq!(
            crate::util::hex::encode(cid.as_bytes()),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn verify_and_multihash() {
        let data = b"block data";
        let cid = Cid::of(data);
        assert!(cid.verify(data));
        assert!(!cid.verify(b"other"));
        let mh = cid.to_multihash();
        assert_eq!(mh.len(), 34);
        assert_eq!(&mh[..2], &[0x12, 0x20]);
        assert_eq!(Cid::from_bytes(&mh[2..]).unwrap(), cid);
        assert!(Cid::from_bytes(&mh).is_err());
    }
}
