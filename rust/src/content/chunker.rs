//! Chunkers: fixed-size and content-defined (rolling-hash) splitting.
//!
//! Fixed chunking is the fast path for model checkpoints (dense binary,
//! no insert/delete edits). The rolling-hash chunker (a Buzhash-style CDC)
//! keeps chunk boundaries stable under insertions, which matters for
//! text-like static assets in the CDN scenario.

/// Default chunk size: 256 KiB (matches the paper's large-payload size).
pub const DEFAULT_CHUNK_SIZE: usize = 256 * 1024;

/// Parameters for FastCDC-style content-defined chunking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CdcParams {
    /// Never cut before this many bytes (also the hash warm-up skip).
    pub min: usize,
    /// Expected chunk size ≈ 2^avg_bits bytes.
    pub avg_bits: u32,
    /// Force a cut at this many bytes.
    pub max: usize,
}

/// Checkpoint chunking: 4 KiB..64 KiB, ~16 KiB expected. Fine enough that
/// a localized parameter update dirties few chunks, coarse enough that a
/// multi-MB blob stays at a few hundred CIDs.
pub const CDC_CHECKPOINT: CdcParams = CdcParams {
    min: 4 * 1024,
    avg_bits: 14,
    max: 64 * 1024,
};

/// Gear table for FastCDC (deterministic, distinct from the Buzhash table).
fn gear_table() -> [u64; 256] {
    let mut t = [0u64; 256];
    let mut rng = crate::util::Rng::new(0x6EA2_CDC1_7F);
    for v in t.iter_mut() {
        *v = rng.next_u64();
    }
    t
}

/// One FastCDC cut decision: offset of the end of the next chunk.
///
/// Normalized chunking: a stricter mask before the expected size and a
/// looser one after, which tightens the size distribution around
/// 2^avg_bits while keeping boundaries content-defined (so identical
/// content reached from different chunk starts re-synchronizes within a
/// few candidate points).
fn cdc_cut(data: &[u8], p: CdcParams, table: &[u64; 256]) -> usize {
    let n = data.len();
    if n <= p.min {
        return n;
    }
    let max = n.min(p.max);
    let avg = (1usize << p.avg_bits).min(max);
    let mask_s = (1u64 << (p.avg_bits + 2)) - 1;
    let mask_l = (1u64 << (p.avg_bits - 2)) - 1;
    let mut h: u64 = 0;
    let mut i = p.min;
    while i < avg {
        h = (h << 1).wrapping_add(table[data[i] as usize]);
        if h & mask_s == 0 {
            return i + 1;
        }
        i += 1;
    }
    while i < max {
        h = (h << 1).wrapping_add(table[data[i] as usize]);
        if h & mask_l == 0 {
            return i + 1;
        }
        i += 1;
    }
    max
}

/// FastCDC content-defined chunking (Gear rolling hash).
///
/// Unlike [`chunk_fixed`], unchanged regions of an edited blob keep their
/// chunk boundaries, so re-publishing checkpoint v+1 reuses the CIDs of
/// untouched chunks from v — the basis of delta checkpoint shipping.
pub fn chunk_cdc(data: &[u8], p: CdcParams) -> Vec<&[u8]> {
    assert!(p.min >= 64 && p.max > p.min, "degenerate CDC bounds");
    assert!(
        (4..=28).contains(&p.avg_bits)
            && (1usize << p.avg_bits) >= p.min
            && (1usize << p.avg_bits) <= p.max,
        "avg must sit between min and max"
    );
    let table = gear_table();
    let mut out = Vec::new();
    let mut rest = data;
    while !rest.is_empty() {
        let cut = cdc_cut(rest, p, &table);
        out.push(&rest[..cut]);
        rest = &rest[cut..];
    }
    out
}

/// Split into fixed-size chunks (last chunk may be short).
pub fn chunk_fixed(data: &[u8], size: usize) -> Vec<&[u8]> {
    assert!(size > 0);
    if data.is_empty() {
        return Vec::new();
    }
    data.chunks(size).collect()
}

/// Buzhash table (deterministic pseudo-random, generated from splitmix).
fn buz_table() -> [u64; 256] {
    let mut t = [0u64; 256];
    let mut rng = crate::util::Rng::new(0xB022_7A81_E5);
    for v in t.iter_mut() {
        *v = rng.next_u64();
    }
    t
}

/// Content-defined chunking with a 64-byte rolling window.
///
/// A boundary is declared when the rolling hash has `mask_bits` low zero
/// bits (expected chunk ≈ 2^mask_bits bytes), clamped to [min, max].
pub fn chunk_rolling(data: &[u8], mask_bits: u32, min: usize, max: usize) -> Vec<&[u8]> {
    const WINDOW: usize = 64;
    assert!(min >= WINDOW && max > min);
    if data.is_empty() {
        return Vec::new();
    }
    let table = buz_table();
    let mask = (1u64 << mask_bits) - 1;
    let mut chunks = Vec::new();
    let mut start = 0usize;
    let mut hash = 0u64;
    let mut i = 0usize;
    while i < data.len() {
        let b = data[i] as usize;
        hash = hash.rotate_left(1) ^ table[b];
        // Only roll out bytes that belong to the current chunk's window
        // (the hash is reset at each boundary).
        if i - start >= WINDOW {
            let old = data[i - WINDOW] as usize;
            hash ^= table[old].rotate_left(WINDOW as u32);
        }
        let len = i - start + 1;
        if (len >= min && (hash & mask) == 0) || len >= max {
            chunks.push(&data[start..=i]);
            start = i + 1;
            hash = 0;
        }
        i += 1;
    }
    if start < data.len() {
        chunks.push(&data[start..]);
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fixed_reassembles() {
        let mut rng = Rng::new(1);
        let data = rng.gen_bytes(1_000_000);
        let chunks = chunk_fixed(&data, DEFAULT_CHUNK_SIZE);
        assert_eq!(chunks.len(), 4);
        let joined: Vec<u8> = chunks.concat();
        assert_eq!(joined, data);
        assert!(chunk_fixed(&[], 100).is_empty());
        assert_eq!(chunk_fixed(&[1, 2, 3], 2), vec![&[1u8, 2][..], &[3u8][..]]);
    }

    #[test]
    fn rolling_reassembles_and_respects_bounds() {
        let mut rng = Rng::new(2);
        let data = rng.gen_bytes(500_000);
        let chunks = chunk_rolling(&data, 13, 2048, 64 * 1024);
        let joined: Vec<u8> = chunks.concat();
        assert_eq!(joined, data);
        for (i, c) in chunks.iter().enumerate() {
            assert!(c.len() <= 64 * 1024);
            if i + 1 < chunks.len() {
                assert!(c.len() >= 2048, "chunk {i} too small: {}", c.len());
            }
        }
        // Expected size ≈ 8 KiB ⇒ between ~30 and ~250 chunks for 500 KB.
        assert!(chunks.len() > 20 && chunks.len() < 260, "{}", chunks.len());
    }

    #[test]
    fn cdc_reassembles_and_respects_bounds() {
        let mut rng = Rng::new(7);
        let data = rng.gen_bytes(900_000);
        let chunks = chunk_cdc(&data, CDC_CHECKPOINT);
        let joined: Vec<u8> = chunks.concat();
        assert_eq!(joined, data);
        for (i, c) in chunks.iter().enumerate() {
            assert!(c.len() <= CDC_CHECKPOINT.max);
            if i + 1 < chunks.len() {
                assert!(c.len() >= CDC_CHECKPOINT.min, "chunk {i}: {}", c.len());
            }
        }
        // Expected ≈ 16 KiB ⇒ roughly 20..160 chunks for 900 KB.
        assert!(
            chunks.len() > 20 && chunks.len() < 160,
            "{} chunks",
            chunks.len()
        );
        assert!(chunk_cdc(&[], CDC_CHECKPOINT).is_empty());
        // Sub-min payloads come back as one chunk.
        assert_eq!(chunk_cdc(&[9u8; 100], CDC_CHECKPOINT), vec![&[9u8; 100][..]]);
    }

    #[test]
    fn cdc_deterministic() {
        let mut rng = Rng::new(8);
        let data = rng.gen_bytes(300_000);
        let a: Vec<usize> = chunk_cdc(&data, CDC_CHECKPOINT).iter().map(|c| c.len()).collect();
        let b: Vec<usize> = chunk_cdc(&data, CDC_CHECKPOINT).iter().map(|c| c.len()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn cdc_reuses_chunks_after_in_place_edit() {
        // A checkpoint-style update: ~10% of the blob rewritten in place
        // (two contiguous bands), total length unchanged. Most chunks must
        // keep their identity so a delta fetch moves only the dirty ones.
        let mut rng = Rng::new(9);
        let data = rng.gen_bytes(1_000_000);
        let mut edited = data.clone();
        for start in [200_000usize, 700_000] {
            let patch = rng.gen_bytes(50_000);
            edited[start..start + 50_000].copy_from_slice(&patch);
        }
        use std::collections::HashSet;
        let c1: HashSet<Vec<u8>> = chunk_cdc(&data, CDC_CHECKPOINT)
            .iter()
            .map(|c| c.to_vec())
            .collect();
        let c2: Vec<Vec<u8>> = chunk_cdc(&edited, CDC_CHECKPOINT)
            .iter()
            .map(|c| c.to_vec())
            .collect();
        let shared = c2.iter().filter(|c| c1.contains(*c)).count();
        assert!(
            shared * 10 >= c2.len() * 7,
            "only {shared}/{} chunks survived a 10% in-place edit",
            c2.len()
        );
        let shared_bytes: usize = c2.iter().filter(|c| c1.contains(*c)).map(|c| c.len()).sum();
        assert!(
            shared_bytes * 4 >= edited.len() * 3,
            "shared bytes {shared_bytes} below 75% of {}",
            edited.len()
        );
    }

    #[test]
    fn cdc_resyncs_after_insertion() {
        let mut rng = Rng::new(10);
        let data = rng.gen_bytes(400_000);
        let mut edited = data.clone();
        let insert = rng.gen_bytes(777);
        edited.splice(90_000..90_000, insert.iter().copied());
        use std::collections::HashSet;
        let c1: HashSet<Vec<u8>> = chunk_cdc(&data, CDC_CHECKPOINT)
            .iter()
            .map(|c| c.to_vec())
            .collect();
        let c2: Vec<Vec<u8>> = chunk_cdc(&edited, CDC_CHECKPOINT)
            .iter()
            .map(|c| c.to_vec())
            .collect();
        let shared = c2.iter().filter(|c| c1.contains(*c)).count();
        assert!(
            shared * 10 >= c2.len() * 6,
            "insertion should shift, not destroy, boundaries: {shared}/{}",
            c2.len()
        );
    }

    #[test]
    fn rolling_boundaries_stable_under_insertion() {
        let mut rng = Rng::new(3);
        let data = rng.gen_bytes(200_000);
        let mut edited = data.clone();
        // Insert 100 bytes near the front.
        let insert = rng.gen_bytes(100);
        edited.splice(5000..5000, insert.iter().copied());

        let c1: Vec<Vec<u8>> = chunk_rolling(&data, 12, 1024, 32 * 1024)
            .into_iter()
            .map(|c| c.to_vec())
            .collect();
        let c2: Vec<Vec<u8>> = chunk_rolling(&edited, 12, 1024, 32 * 1024)
            .into_iter()
            .map(|c| c.to_vec())
            .collect();
        use std::collections::HashSet;
        let s1: HashSet<&Vec<u8>> = c1.iter().collect();
        let shared = c2.iter().filter(|c| s1.contains(c)).count();
        // Most chunks survive the edit (content-defined boundaries).
        assert!(
            shared * 10 >= c2.len() * 7,
            "only {shared}/{} chunks shared",
            c2.len()
        );
        // Fixed chunking, by contrast, shares almost nothing.
        let f1: HashSet<Vec<u8>> = chunk_fixed(&data, 8192).iter().map(|c| c.to_vec()).collect();
        let f_shared = chunk_fixed(&edited, 8192)
            .iter()
            .filter(|c| f1.contains(**c))
            .count();
        assert!(f_shared <= 1, "fixed chunking shared {f_shared}");
    }
}
