//! Chunkers: fixed-size and content-defined (rolling-hash) splitting.
//!
//! Fixed chunking is the fast path for model checkpoints (dense binary,
//! no insert/delete edits). The rolling-hash chunker (a Buzhash-style CDC)
//! keeps chunk boundaries stable under insertions, which matters for
//! text-like static assets in the CDN scenario.

/// Default chunk size: 256 KiB (matches the paper's large-payload size).
pub const DEFAULT_CHUNK_SIZE: usize = 256 * 1024;

/// Split into fixed-size chunks (last chunk may be short).
pub fn chunk_fixed(data: &[u8], size: usize) -> Vec<&[u8]> {
    assert!(size > 0);
    if data.is_empty() {
        return Vec::new();
    }
    data.chunks(size).collect()
}

/// Buzhash table (deterministic pseudo-random, generated from splitmix).
fn buz_table() -> [u64; 256] {
    let mut t = [0u64; 256];
    let mut rng = crate::util::Rng::new(0xB022_7A81_E5);
    for v in t.iter_mut() {
        *v = rng.next_u64();
    }
    t
}

/// Content-defined chunking with a 64-byte rolling window.
///
/// A boundary is declared when the rolling hash has `mask_bits` low zero
/// bits (expected chunk ≈ 2^mask_bits bytes), clamped to [min, max].
pub fn chunk_rolling(data: &[u8], mask_bits: u32, min: usize, max: usize) -> Vec<&[u8]> {
    const WINDOW: usize = 64;
    assert!(min >= WINDOW && max > min);
    if data.is_empty() {
        return Vec::new();
    }
    let table = buz_table();
    let mask = (1u64 << mask_bits) - 1;
    let mut chunks = Vec::new();
    let mut start = 0usize;
    let mut hash = 0u64;
    let mut i = 0usize;
    while i < data.len() {
        let b = data[i] as usize;
        hash = hash.rotate_left(1) ^ table[b];
        // Only roll out bytes that belong to the current chunk's window
        // (the hash is reset at each boundary).
        if i - start >= WINDOW {
            let old = data[i - WINDOW] as usize;
            hash ^= table[old].rotate_left(WINDOW as u32);
        }
        let len = i - start + 1;
        if (len >= min && (hash & mask) == 0) || len >= max {
            chunks.push(&data[start..=i]);
            start = i + 1;
            hash = 0;
        }
        i += 1;
    }
    if start < data.len() {
        chunks.push(&data[start..]);
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fixed_reassembles() {
        let mut rng = Rng::new(1);
        let data = rng.gen_bytes(1_000_000);
        let chunks = chunk_fixed(&data, DEFAULT_CHUNK_SIZE);
        assert_eq!(chunks.len(), 4);
        let joined: Vec<u8> = chunks.concat();
        assert_eq!(joined, data);
        assert!(chunk_fixed(&[], 100).is_empty());
        assert_eq!(chunk_fixed(&[1, 2, 3], 2), vec![&[1u8, 2][..], &[3u8][..]]);
    }

    #[test]
    fn rolling_reassembles_and_respects_bounds() {
        let mut rng = Rng::new(2);
        let data = rng.gen_bytes(500_000);
        let chunks = chunk_rolling(&data, 13, 2048, 64 * 1024);
        let joined: Vec<u8> = chunks.concat();
        assert_eq!(joined, data);
        for (i, c) in chunks.iter().enumerate() {
            assert!(c.len() <= 64 * 1024);
            if i + 1 < chunks.len() {
                assert!(c.len() >= 2048, "chunk {i} too small: {}", c.len());
            }
        }
        // Expected size ≈ 8 KiB ⇒ between ~30 and ~250 chunks for 500 KB.
        assert!(chunks.len() > 20 && chunks.len() < 260, "{}", chunks.len());
    }

    #[test]
    fn rolling_boundaries_stable_under_insertion() {
        let mut rng = Rng::new(3);
        let data = rng.gen_bytes(200_000);
        let mut edited = data.clone();
        // Insert 100 bytes near the front.
        let insert = rng.gen_bytes(100);
        edited.splice(5000..5000, insert.iter().copied());

        let c1: Vec<Vec<u8>> = chunk_rolling(&data, 12, 1024, 32 * 1024)
            .into_iter()
            .map(|c| c.to_vec())
            .collect();
        let c2: Vec<Vec<u8>> = chunk_rolling(&edited, 12, 1024, 32 * 1024)
            .into_iter()
            .map(|c| c.to_vec())
            .collect();
        use std::collections::HashSet;
        let s1: HashSet<&Vec<u8>> = c1.iter().collect();
        let shared = c2.iter().filter(|c| s1.contains(c)).count();
        // Most chunks survive the edit (content-defined boundaries).
        assert!(
            shared * 10 >= c2.len() * 7,
            "only {shared}/{} chunks shared",
            c2.len()
        );
        // Fixed chunking, by contrast, shares almost nothing.
        let f1: HashSet<Vec<u8>> = chunk_fixed(&data, 8192).iter().map(|c| c.to_vec()).collect();
        let f_shared = chunk_fixed(&edited, 8192)
            .iter()
            .filter(|c| f1.contains(**c))
            .count();
        assert!(f_shared <= 1, "fixed chunking shared {f_shared}");
    }
}
