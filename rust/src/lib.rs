//! # Lattica
//!
//! A decentralized cross-NAT communication framework for scalable AI
//! inference and training (reproduction of the Gradient CS.DC 2025 paper).
//!
//! Lattica composes three planes:
//!
//! 1. **Connectivity** — a libp2p-style swarm over simulated transports with
//!    multi-protocol NAT traversal (AutoNAT, circuit relay, DCUtR hole
//!    punching, rendezvous) and Noise-style authenticated encryption.
//! 2. **Data** — content-addressed blocks (CIDs), Bitswap block exchange,
//!    a Kademlia DHT for provider discovery, and a CRDT store for
//!    eventually-consistent verifiable state.
//! 3. **Compute** — a dual-plane RPC protocol (unary control plane +
//!    credit-backpressured streaming data plane) with a typed service
//!    layer on top: servers register named handlers on a `ServiceRouter`,
//!    clients call through `Stub`s with deadline propagation, retries,
//!    hedging and failover (`rpc::service`, `rpc::stub`) — carrying
//!    sharded inference and collaborative training of an AOT-compiled
//!    JAX/Pallas transformer executed through PJRT (`runtime`).
//!
//! The network is a deterministic discrete-event simulation (`netsim`) so
//! NAT semantics and WAN conditions are exactly reproducible; see
//! DESIGN.md §3 for the substitution rationale. Start with
//! [`node::LatticaNode`] and the `examples/` directory.

pub mod util;
pub mod wire;
pub mod crypto;
pub mod identity;
pub mod multiaddr;
pub mod netsim;
pub mod transport;
pub mod swarm;
pub mod runtime;
pub mod content;
pub mod crdt;
pub mod protocols;
pub mod rpc;
pub mod metrics;
pub mod node;
pub mod route;
pub mod model;
pub mod shard;
pub mod trainer;
pub mod scenarios;
