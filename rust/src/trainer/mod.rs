//! The training driver: steps the AOT `train_step` artifact and publishes
//! checkpoints as content-addressed blobs.
//!
//! Holds the full optimizer state (params, Adam moments, step counter) as
//! host tensors between steps, so the whole training loop runs from Rust
//! with Python nowhere on the path.

use crate::runtime::{DType, Engine, Tensor};
use crate::util::Rng;
use anyhow::Result;

/// Synthetic sequence task: x[t] = (start + delta·t) mod vocab.
/// Learnable (loss → ~0) yet trivially generated on any node.
pub fn synthetic_batch(rng: &mut Rng, batch: usize, seq_plus1: usize, vocab: usize) -> Tensor {
    let mut data = Vec::with_capacity(batch * seq_plus1);
    for _ in 0..batch {
        let start = rng.gen_range(vocab as u64) as i32;
        let delta = 1 + rng.gen_range(4) as i32;
        for t in 0..seq_plus1 as i32 {
            data.push((start + delta * t).rem_euclid(vocab as i32));
        }
    }
    Tensor::from_i32(&[batch, seq_plus1], &data)
}

/// Training state (flat tensors, mirrors `train_step`'s signature).
pub struct Trainer {
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: Tensor,
    pub losses: Vec<f32>,
    rng: Rng,
}

impl Trainer {
    /// Initialize from the manifest's init_params.bin.
    pub fn new(engine: &Engine, seed: u64) -> Result<Trainer> {
        let params = engine.manifest.load_init_params()?;
        let m = params
            .iter()
            .map(|p| Tensor::zeros(DType::F32, &p.shape))
            .collect::<Vec<_>>();
        let v = m.clone();
        Ok(Trainer {
            params,
            m,
            v,
            step: Tensor::scalar_i32(0),
            losses: Vec::new(),
            rng: Rng::new(seed),
        })
    }

    /// Run one optimizer step on a fresh synthetic batch; returns the loss.
    pub fn step(&mut self, engine: &mut Engine) -> Result<f32> {
        let cfg = engine.manifest.config.clone();
        let batch = synthetic_batch(&mut self.rng, cfg.batch, cfg.seq_len + 1, cfg.vocab);
        let n = self.params.len();
        let mut inputs = Vec::with_capacity(3 * n + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());
        inputs.push(self.step.clone());
        inputs.push(batch);
        let outs = engine.run("train_step", &inputs)?;
        anyhow::ensure!(outs.len() == 3 * n + 2, "unexpected train_step outputs");
        self.params = outs[..n].to_vec();
        self.m = outs[n..2 * n].to_vec();
        self.v = outs[2 * n..3 * n].to_vec();
        self.step = outs[3 * n].clone();
        let loss = outs[3 * n + 1].as_f32()?[0];
        self.losses.push(loss);
        Ok(loss)
    }

    /// Evaluate loss on a held-out synthetic batch without updating.
    pub fn eval(&mut self, engine: &mut Engine) -> Result<f32> {
        let cfg = engine.manifest.config.clone();
        let batch = synthetic_batch(&mut self.rng, cfg.batch, cfg.seq_len + 1, cfg.vocab);
        let mut inputs = Vec::with_capacity(self.params.len() + 1);
        inputs.extend(self.params.iter().cloned());
        inputs.push(batch);
        let outs = engine.run("eval_loss", &inputs)?;
        Ok(outs[0].as_f32()?[0])
    }

    pub fn current_step(&self) -> i32 {
        self.step.as_i32().map(|v| v[0]).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_batch_shape_and_range() {
        let mut rng = Rng::new(3);
        let t = synthetic_batch(&mut rng, 4, 65, 256);
        assert_eq!(t.shape, vec![4, 65]);
        let vals = t.as_i32().unwrap();
        assert!(vals.iter().all(|&v| (0..256).contains(&v)));
        // Arithmetic structure: consecutive deltas constant per row.
        let row = &vals[0..65];
        let d = (row[1] - row[0]).rem_euclid(256);
        for w in row.windows(2) {
            assert_eq!((w[1] - w[0]).rem_euclid(256), d);
        }
    }

    #[test]
    fn trainer_loss_decreases_e2e() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut engine = Engine::load(dir).unwrap();
        let mut tr = Trainer::new(&engine, 7).unwrap();
        let first = tr.step(&mut engine).unwrap();
        for _ in 0..9 {
            tr.step(&mut engine).unwrap();
        }
        let last = *tr.losses.last().unwrap();
        assert_eq!(tr.current_step(), 10);
        assert!(last < first, "loss {first} → {last}");
    }
}
