//! The Lattica node: swarm + protocols + content + CRDT + RPC, composed
//! per role and driven by the simulator event loop.
//!
//! This is the deployment unit: a bootstrap/relay/rendezvous server, a
//! trainer, an inference shard or an edge client are all `LatticaNode`s
//! with different [`NodeConfig`] roles (the launcher in `main.rs` and the
//! examples build topologies out of them).

pub mod config;
pub mod relay;

use crate::content::{Blockstore, Chunking, Cid, DagManifest};
use crate::crdt::CrdtStore;
use crate::identity::{Keypair, PeerId};
use crate::multiaddr::{Multiaddr, SimAddr};
use crate::netsim::{Endpoint, EndpointId, Net, Time, World, MILLI, SECOND};
use crate::protocols::autonat::{Autonat, AUTONAT_PROTO, PROBE_MAGIC};
use crate::protocols::bitswap::{Bitswap, BitswapEvent, BITSWAP_PROTO};
use crate::protocols::dcutr::{Dcutr, DcutrEvent, DCUTR_PROTO};
use crate::protocols::gossip::{Gossip, GossipEvent, GOSSIP_PROTO};
use crate::protocols::identify::{Identify, IDENTIFY_PROTO};
use crate::protocols::kad::{Kademlia, KadEvent, PeerEntry, KAD_PROTO};
use crate::protocols::ping::{Ping, PingEvent, PING_PROTO};
use crate::protocols::rendezvous::{Rendezvous, RendezvousEvent, RENDEZVOUS_PROTO};
use crate::protocols::Ctx;
use crate::rpc::{RpcEvent, RpcNode, Service, ServiceRouter, RPC_PROTO, RPC_STREAM_PROTO};
use crate::swarm::{Swarm, SwarmConfig, SwarmEvent, TIMER_SWARM_TICK};
use crate::wire::Message;
use anyhow::Result;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

pub use config::NodeConfig;
pub use relay::{RelayManager, RELAY_ADS_TOPIC};

/// Timer tokens (swarm owns token 1).
pub const TIMER_PROTO_TICK: u64 = 2;
/// Protocol housekeeping period.
pub const PROTO_TICK_PERIOD: Time = 250 * MILLI;

/// Application-level events surfaced by the node.
#[derive(Debug)]
pub enum NodeEvent {
    PeerConnected { peer: PeerId, relayed: bool },
    PeerDisconnected { peer: PeerId },
    Kad(KadEvent),
    Bitswap(BitswapEvent),
    Gossip(GossipEvent),
    Rpc(RpcEvent),
    Rendezvous(RendezvousEvent),
    Ping(PingEvent),
    PunchResult { peer: PeerId, success: bool },
    ObservedAddr { addr: SimAddr },
}

/// Raw-event adapter attached to a node. RPC request handling belongs on
/// the [`ServiceRouter`] (see [`LatticaNode::register_service`]); an
/// `App` is the thin escape hatch for everything else — reacting to
/// connectivity changes, gossip, or client-side RPC completions that
/// must resolve a deferred [`crate::rpc::Reply`]. Events are offered to
/// the app after router dispatch; returning `None` consumes the event,
/// returning it back leaves it for external polling.
pub trait App {
    fn handle(
        &mut self,
        node: &mut LatticaNode,
        net: &mut Net,
        ev: NodeEvent,
    ) -> Option<NodeEvent>;
}

/// See module docs.
pub struct LatticaNode {
    pub cfg: NodeConfig,
    pub swarm: Swarm,
    pub kad: Kademlia,
    pub bitswap: Bitswap,
    pub gossip: Gossip,
    pub rpc: RpcNode,
    pub ping: Ping,
    pub identify: Identify,
    pub autonat: Autonat,
    pub rendezvous: Rendezvous,
    pub dcutr: Dcutr,
    /// Relay autoscaling: ad directory, reservation upkeep, promotion.
    pub relay_mgr: RelayManager,
    /// EWMA ping RTTs per peer, consumed by the inference-plane router
    /// ([`crate::route::LayerRouter`]) and piggybacked on layer ads.
    pub rtt: crate::route::RttTable,
    pub blockstore: Blockstore,
    pub crdt: CrdtStore,
    /// Attached application logic (served inline, so RPC handlers add no
    /// artificial polling latency).
    pub app: Option<Box<dyn App>>,
    /// Registered RPC services; `Option` so the pump can take it while
    /// handlers hold `&mut LatticaNode`.
    router: Option<ServiceRouter>,
    /// Blob-sync driver state (see [`LatticaNode::sync_blob`]).
    blob_sync: std::collections::HashMap<Cid, BlobSync>,
    /// Outstanding provider-discovery queries: kad query id → blob root.
    discovery: std::collections::HashMap<u64, Cid>,
    events: VecDeque<NodeEvent>,
    tick_armed: bool,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum BlobSyncState {
    FetchingManifest,
    FetchingChunks,
    Complete,
}

struct BlobSync {
    state: BlobSyncState,
    /// (local block count, virtual time) at the last observed progress.
    progress: (usize, Time),
    /// Active Bitswap session for the current phase.
    session: Option<u64>,
    /// When the last `get_providers` discovery round was issued.
    last_discovery: Time,
    /// Whether a discovery query is currently in flight.
    discovering: bool,
}

/// Restart a stalled fetch after this much virtual time without progress
/// (sessions can erode their provider lists across reconnects).
const BLOB_STALL_RESTART: Time = 10 * SECOND;
/// How often a syncing node polls the DHT for additional providers
/// (swarm mode only).
const DISCOVERY_INTERVAL: Time = 2 * SECOND;

impl LatticaNode {
    /// Construct and register a node on `host` in the world. Binds the
    /// configured port and arms the protocol tick.
    pub fn spawn(world: &mut World, host: u32, cfg: NodeConfig) -> Rc<RefCell<LatticaNode>> {
        let keypair = Keypair::from_seed(cfg.seed);
        let local_peer = keypair.peer_id();
        let addr = SimAddr::new(host, cfg.port);
        let eid = world.next_endpoint_id();
        let mut swarm_cfg = SwarmConfig {
            relay_enabled: cfg.relay_enabled,
            max_circuits: cfg.relay_max_circuits,
            max_reservations: cfg.relay_max_reservations,
            relay_egress_bps: cfg.relay_egress_bps,
            ..SwarmConfig::default()
        };
        swarm_cfg.conn.cc = cfg.cc;
        let rng = world.net.rng.fork();
        let swarm = Swarm::new(keypair, eid, addr, swarm_cfg, rng);
        let protocols: Vec<String> = [
            KAD_PROTO,
            BITSWAP_PROTO,
            GOSSIP_PROTO,
            RPC_PROTO,
            RPC_STREAM_PROTO,
            PING_PROTO,
            IDENTIFY_PROTO,
            AUTONAT_PROTO,
            RENDEZVOUS_PROTO,
            DCUTR_PROTO,
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut bitswap = Bitswap::new();
        bitswap.compact_control = cfg.compact_control;
        let mut gossip = Gossip::new(local_peer);
        gossip.lazy_push = cfg.compact_control;
        let node = LatticaNode {
            kad: Kademlia::new(local_peer, host, cfg.port),
            bitswap,
            gossip,
            rpc: RpcNode::new(),
            ping: Ping::new(),
            identify: Identify::new(protocols),
            autonat: Autonat::new(),
            rendezvous: Rendezvous::new(cfg.rendezvous_server),
            dcutr: Dcutr::new(),
            relay_mgr: RelayManager::new(),
            rtt: crate::route::RttTable::new(),
            blockstore: Blockstore::new(),
            crdt: CrdtStore::new(),
            app: None,
            router: None,
            blob_sync: std::collections::HashMap::new(),
            discovery: std::collections::HashMap::new(),
            swarm,
            cfg,
            events: VecDeque::new(),
            tick_armed: false,
        };
        let rc = Rc::new(RefCell::new(node));
        let got = world.add_endpoint(rc.clone());
        debug_assert_eq!(got, eid);
        world.net.bind(eid, addr).expect("bind node port");
        {
            let mut n = rc.borrow_mut();
            n.arm_proto_tick(&mut world.net);
            // Everyone follows the relay-ad topic: NATted nodes pick
            // relays from it, public nodes watch it for saturation.
            let n = &mut *n;
            let mut ctx = Ctx::new(&mut n.swarm, &mut world.net);
            n.gossip.subscribe(&mut ctx, RELAY_ADS_TOPIC);
        }
        rc
    }

    pub fn peer_id(&self) -> PeerId {
        self.swarm.local_peer
    }

    pub fn endpoint_id(&self) -> EndpointId {
        self.swarm.endpoint_id
    }

    pub fn listen_addr(&self) -> Multiaddr {
        Multiaddr::direct(self.swarm.local_addr, self.cfg.proto).with_peer(self.peer_id())
    }

    pub fn poll_event(&mut self) -> Option<NodeEvent> {
        self.events.pop_front()
    }

    /// Register an RPC service: its unary methods and stream handler are
    /// dispatched inline in the node pump, replacing ad-hoc
    /// `RpcEvent::Request` match arms. Safe to call from inside a running
    /// handler (the registration is merged after dispatch returns).
    ///
    /// The service's admission policy (see [`Service::with_admission`]) —
    /// or the node-wide default from `NodeConfig::admission_rate` when
    /// the service has none — is installed into the RPC layer so shed
    /// requests are refused before payload decode.
    pub fn register_service(&mut self, mut svc: Service) {
        if let Some(p) = svc.take_admission() {
            self.rpc.admission.set_policy(svc.name(), p);
        } else if self.cfg.admission_rate > 0.0 {
            self.rpc.admission.set_policy(
                svc.name(),
                crate::rpc::AdmissionPolicy::rate(self.cfg.admission_rate, self.cfg.admission_burst),
            );
        }
        self.router.get_or_insert_with(ServiceRouter::new).register(svc);
    }

    /// Counters of the service router (zeroes when none is registered),
    /// overlaid with the RPC layer's pre-decode shed count so operators
    /// read sheds alongside the dispatch counters.
    pub fn router_stats(&self) -> crate::metrics::RouterStats {
        let mut s = self.router.as_ref().map(|r| r.stats).unwrap_or_default();
        s.shed_predecode = self.rpc.admission.stats.shed_predecode;
        s
    }

    pub fn drain_events(&mut self) -> Vec<NodeEvent> {
        self.events.drain(..).collect()
    }

    fn arm_proto_tick(&mut self, net: &mut Net) {
        if !self.tick_armed {
            net.set_timer(self.swarm.endpoint_id, PROTO_TICK_PERIOD, TIMER_PROTO_TICK);
            self.tick_armed = true;
        }
    }

    // ------------------------------------------------------------------
    // High-level operations (the SDK surface)
    // ------------------------------------------------------------------

    /// Dial a multiaddr.
    pub fn dial(&mut self, net: &mut Net, addr: &Multiaddr) -> Result<u64> {
        self.swarm.dial(net, addr)
    }

    /// Bootstrap into the DHT via a known peer: add it, then self-lookup.
    pub fn bootstrap(&mut self, net: &mut Net, entry: PeerEntry) {
        let mut ctx = Ctx::new(&mut self.swarm, net);
        self.kad.add_address(&mut ctx, entry);
        let key = *self.kad.table.local.as_bytes();
        self.kad.find_node(&mut ctx, key);
    }

    /// Take this node off the network (the churn engine's stop path).
    ///
    /// `clean == true` is a graceful leave: every connection is closed with
    /// a "node shutdown" goodbye that peers observe immediately (and use to
    /// drop us from their routing tables). `clean == false` models a crash:
    /// nothing is sent, peers discover the loss via request timeouts and
    /// idle teardown. Either way the port is unbound so a later restart can
    /// re-bind it; the caller must also remove the endpoint from the world.
    pub fn shutdown(&mut self, net: &mut Net, clean: bool) {
        if clean {
            for cid in self.swarm.connection_ids() {
                self.swarm.close_conn(net, cid, "node shutdown");
            }
        }
        net.unbind(self.swarm.local_addr);
    }

    /// Publish a blob: chunk + store + announce provider records on the DHT.
    /// Returns the root CID.
    pub fn publish_blob(
        &mut self,
        net: &mut Net,
        name: &str,
        version: u64,
        data: &[u8],
        chunk_size: usize,
    ) -> Cid {
        self.publish_blob_chunked(net, name, version, data, Chunking::Fixed(chunk_size))
    }

    /// [`LatticaNode::publish_blob`] with an explicit chunking policy
    /// (checkpoint publishers use CDC so version v+1 reuses v's chunks).
    pub fn publish_blob_chunked(
        &mut self,
        net: &mut Net,
        name: &str,
        version: u64,
        data: &[u8],
        chunking: Chunking,
    ) -> Cid {
        let (root, manifest) =
            DagManifest::publish_chunked(&mut self.blockstore, name, version, data, chunking);
        // The manifest is session-startup metadata: never choke it.
        self.bitswap.choke_exempt.insert(root);
        let mut ctx = Ctx::new(&mut self.swarm, net);
        // Known chunk list → compact (root, index-set) control messages.
        self.bitswap
            .register_manifest(&mut ctx, &self.blockstore, root, &manifest.chunks);
        self.kad.provide(&mut ctx, root.to_key());
        for c in &manifest.chunks {
            // Providing the root is usually enough (fetchers ask the same
            // provider set for chunks), but announcing chunks too lets
            // partial caches serve. One-shot: only the root is enrolled
            // for periodic republish, so publishing many chunks doesn't
            // accumulate permanent background query load.
            self.kad.provide_once(&mut ctx, c.to_key());
        }
        root
    }

    /// Fetch a blob by root CID from a known provider set.
    pub fn fetch_blob(&mut self, net: &mut Net, root: Cid, providers: Vec<PeerId>) -> u64 {
        let mut ctx = Ctx::new(&mut self.swarm, net);
        // First fetch the manifest block, then its chunks (the bitswap
        // session state machine handles both phases via completion events;
        // the node-level helper in examples drives phase 2).
        self.bitswap
            .fetch(&mut ctx, &self.blockstore, vec![root], providers)
    }

    /// Fetch all chunks listed by a locally-present manifest.
    pub fn fetch_manifest_chunks(
        &mut self,
        net: &mut Net,
        root: &Cid,
        providers: Vec<PeerId>,
    ) -> Result<u64> {
        let manifest = DagManifest::load(&self.blockstore, root)?;
        let missing = manifest.missing(&self.blockstore);
        let mut ctx = Ctx::new(&mut self.swarm, net);
        // Known chunk list → compact (root, index-set) control messages.
        self.bitswap
            .register_manifest(&mut ctx, &self.blockstore, *root, &manifest.chunks);
        Ok(self.bitswap.fetch(&mut ctx, &self.blockstore, missing, providers))
    }

    /// Idempotent blob-sync driver: call repeatedly (e.g. once per poll
    /// loop iteration) until it returns true. Fetches the manifest, then
    /// the chunks, creating each Bitswap session exactly once.
    ///
    /// With [`NodeConfig::swarm_sync`] on, the driver additionally
    /// (a) announces this node as a one-shot provider of `root` as soon as
    /// the manifest lands (seeder promotion: every replica serves the
    /// swarm mid-download), and (b) polls `kad::get_providers` every
    /// [`DISCOVERY_INTERVAL`], feeding discovered seeders into the running
    /// Bitswap session.
    pub fn sync_blob(&mut self, net: &mut Net, root: Cid, providers: &[PeerId]) -> bool {
        let now = net.now();
        let blocks_now = self.blockstore.len();
        // Fast path for finished blobs: no provider-list work.
        if self.blob_sync.get(&root).map(|b| b.state) == Some(BlobSyncState::Complete) {
            return true;
        }
        // Swarm overlay seeding: peers we are already connected to (the
        // gossip/DHT mesh) are candidate seeders — one WANT_HAVE reveals
        // the truth, and fellow fetchers push HAVEs as chunks land, so
        // availability spreads at RTT timescale without waiting on DHT
        // discovery rounds.
        let providers: Vec<PeerId> = if self.cfg.swarm_sync {
            let mut v = providers.to_vec();
            for p in self.swarm.connected_peers() {
                if !v.contains(&p) {
                    v.push(p);
                }
            }
            v
        } else {
            providers.to_vec()
        };
        let providers = providers.as_slice();
        let state = self
            .blob_sync
            .get(&root)
            .map(|b| b.state)
            .unwrap_or(BlobSyncState::FetchingManifest);
        let mark = |node: &mut Self, st: BlobSyncState, session: Option<u64>| {
            let (last_discovery, discovering) = node
                .blob_sync
                .get(&root)
                .map(|b| (b.last_discovery, b.discovering))
                .unwrap_or((0, false));
            node.blob_sync.insert(
                root,
                BlobSync {
                    state: st,
                    progress: (blocks_now, now),
                    session,
                    last_discovery,
                    discovering,
                },
            );
        };
        match state {
            BlobSyncState::Complete => true,
            BlobSyncState::FetchingManifest => {
                if self.blockstore.has(&root) {
                    // Manifest arrived: move on to chunks.
                    let sid = self
                        .fetch_manifest_chunks(net, &root, providers.to_vec())
                        .ok();
                    mark(self, BlobSyncState::FetchingChunks, sid);
                    if self.cfg.swarm_sync {
                        // Seeder promotion: we hold the manifest (and will
                        // hold chunks shortly) — become discoverable now so
                        // later fetchers spread load off the publisher.
                        let mut ctx = Ctx::new(&mut self.swarm, net);
                        self.kad.provide_once(&mut ctx, root.to_key());
                    }
                    self.discover_providers(net, root);
                    false
                } else {
                    let restart = match self.blob_sync.get(&root) {
                        None => true,
                        Some(b) => now.saturating_sub(b.progress.1) > BLOB_STALL_RESTART,
                    };
                    if restart {
                        let sid = self.fetch_blob(net, root, providers.to_vec());
                        mark(self, BlobSyncState::FetchingManifest, Some(sid));
                    }
                    self.discover_providers(net, root);
                    false
                }
            }
            BlobSyncState::FetchingChunks => {
                let complete = DagManifest::load(&self.blockstore, &root)
                    .map(|m| m.is_complete(&self.blockstore))
                    .unwrap_or(false);
                if complete {
                    mark(self, BlobSyncState::Complete, None);
                    return true;
                }
                // Progress tracking + stalled-session restart.
                let entry = self.blob_sync.get(&root).map(|b| (b.progress, b.session));
                match entry {
                    Some(((prev_blocks, _since), sid)) if blocks_now > prev_blocks => {
                        mark(self, BlobSyncState::FetchingChunks, sid);
                    }
                    Some(((_, since), _)) if now.saturating_sub(since) > BLOB_STALL_RESTART => {
                        let sid = self
                            .fetch_manifest_chunks(net, &root, providers.to_vec())
                            .ok();
                        mark(self, BlobSyncState::FetchingChunks, sid);
                    }
                    _ => {}
                }
                self.discover_providers(net, root);
                false
            }
        }
    }

    /// Issue a periodic `get_providers(root)` round (swarm mode). Results
    /// are intercepted in `pump` and fed into the blob's Bitswap session.
    fn discover_providers(&mut self, net: &mut Net, root: Cid) {
        if !self.cfg.swarm_sync {
            return;
        }
        let now = net.now();
        let due = self.blob_sync.get(&root).is_some_and(|b| {
            !b.discovering && now.saturating_sub(b.last_discovery) >= DISCOVERY_INTERVAL
        });
        if !due {
            return;
        }
        let qid = {
            let mut ctx = Ctx::new(&mut self.swarm, net);
            self.kad.get_providers(&mut ctx, root.to_key())
        };
        self.discovery.insert(qid, root);
        if let Some(b) = self.blob_sync.get_mut(&root) {
            b.last_discovery = now;
            b.discovering = true;
        }
    }

    // ------------------------------------------------------------------
    // Event plumbing
    // ------------------------------------------------------------------

    fn pump(&mut self, net: &mut Net) {
        // Move swarm events through protocol dispatch until quiescent.
        loop {
            let Some(ev) = self.swarm.poll_event() else { break };
            self.dispatch_swarm_event(net, ev);
        }
        // Collect protocol events for the application.
        while let Some(e) = self.kad.poll_event() {
            // Intercept provider-discovery rounds issued by sync_blob:
            // feed the seeders into the blob's Bitswap session instead of
            // surfacing a node-internal query to the app.
            if let KadEvent::QueryFinished { query_id, providers, .. } = &e {
                if let Some(root) = self.discovery.remove(query_id) {
                    for p in providers {
                        self.swarm.peerstore.add_address(p.id, p.to_multiaddr());
                    }
                    let session = self.blob_sync.get_mut(&root).and_then(|b| {
                        b.discovering = false;
                        b.session
                    });
                    if let Some(sid) = session {
                        let peers: Vec<PeerId> = providers.iter().map(|p| p.id).collect();
                        let mut ctx = Ctx::new(&mut self.swarm, net);
                        self.bitswap.add_providers(&mut ctx, sid, peers);
                    }
                    continue;
                }
            }
            self.events.push_back(NodeEvent::Kad(e));
        }
        while let Some(e) = self.bitswap.poll_event() {
            self.events.push_back(NodeEvent::Bitswap(e));
        }
        while let Some(e) = self.gossip.poll_event() {
            // Relay ads are node plumbing, not application traffic: feed
            // them to the relay manager instead of surfacing them.
            if let GossipEvent::Received { topic, data, .. } = &e {
                if topic == RELAY_ADS_TOPIC {
                    let _ = self.relay_mgr.handle_ad(net.now(), data);
                    continue;
                }
            }
            self.events.push_back(NodeEvent::Gossip(e));
        }
        while let Some(e) = self.rpc.poll_event() {
            // Service dispatch runs inline here: registered handlers see
            // requests with no polling latency, and only unowned events
            // (client-side completions, unrouted streams) surface. The
            // router is taken so handlers can hold `&mut LatticaNode`;
            // services they register meanwhile land in a fresh router and
            // are merged back.
            let e = match self.router.take() {
                Some(mut r) => {
                    let out = r.dispatch(self, net, e);
                    if let Some(registered_during_dispatch) = self.router.take() {
                        r.merge(registered_during_dispatch);
                    }
                    self.router = Some(r);
                    out
                }
                None => Some(e),
            };
            if let Some(e) = e {
                self.events.push_back(NodeEvent::Rpc(e));
            }
        }
        // Replies dropped without being sent (handler bug, shed queue
        // entry, abandoned deferral) answer `Unavailable` now, so the
        // caller fails over immediately instead of burning its whole
        // deadline waiting on a response that will never come.
        for h in self.rpc.take_orphaned() {
            self.rpc.replies_dropped += 1;
            let LatticaNode { swarm, rpc, .. } = self;
            let mut ctx = Ctx::new(swarm, net);
            let _ = rpc.respond_detail(
                &mut ctx,
                h,
                crate::rpc::Status::Unavailable,
                crate::util::buf::Buf::new(),
                "reply dropped",
            );
        }
        while let Some(e) = self.rendezvous.poll_event() {
            self.events.push_back(NodeEvent::Rendezvous(e));
        }
        while let Some(e) = self.ping.poll_event() {
            // Feed the RTT table the router costs chains with before the
            // event surfaces to the app.
            if let PingEvent::Rtt { peer, rtt } = &e {
                self.rtt.observe(*peer, *rtt);
            }
            self.events.push_back(NodeEvent::Ping(e));
        }
        while let Some(_e) = self.identify.poll_event() {}
        while let Some(_e) = self.autonat.poll_event() {}
        while let Some(e) = self.dcutr.poll_event() {
            // A failed/denied upgrade surfaces like a failed punch: the
            // connection stays relayed and the app can keep using it.
            if let DcutrEvent::PunchFailed { peer, .. } = e {
                self.events
                    .push_back(NodeEvent::PunchResult { peer, success: false });
            }
        }
        // Offer events to the attached app (take/put avoids double borrow).
        if let Some(mut app) = self.app.take() {
            let pending: Vec<NodeEvent> = self.events.drain(..).collect();
            for ev in pending {
                if let Some(back) = app.handle(self, net, ev) {
                    self.events.push_back(back);
                }
            }
            // The app may have triggered more protocol activity.
            if self.app.is_none() {
                self.app = Some(app);
            }
        }
    }

    fn dispatch_swarm_event(&mut self, net: &mut Net, ev: SwarmEvent) {
        match ev {
            SwarmEvent::ConnEstablished {
                cid: _,
                peer,
                role: _,
                relayed,
                remote_addr,
            } => {
                let mut ctx = Ctx::new(&mut self.swarm, net);
                self.kad.on_peer_connected(&mut ctx, peer);
                self.gossip.on_peer_connected(&mut ctx, peer);
                self.bitswap.on_peer_connected(&mut ctx, peer);
                self.identify.on_peer_connected(&mut ctx, peer, remote_addr);
                // Learn the peer's DHT entry from its observed endpoint.
                if !relayed {
                    self.kad.add_address(
                        &mut ctx,
                        PeerEntry {
                            id: peer,
                            host: remote_addr.host,
                            port: remote_addr.port,
                        },
                    );
                }
                self.events
                    .push_back(NodeEvent::PeerConnected { peer, relayed });
            }
            SwarmEvent::ConnClosed { cid, peer, reason } => {
                self.rpc.on_conn_closed(cid);
                {
                    // Fail over kad requests that were in flight on this
                    // connection's streams (churn resilience).
                    let mut ctx = Ctx::new(&mut self.swarm, net);
                    self.kad.on_conn_closed(&mut ctx, cid, peer, &reason);
                }
                if let Some(p) = peer {
                    let mut ctx = Ctx::new(&mut self.swarm, net);
                    self.bitswap.on_peer_disconnected(&mut ctx, p);
                    self.gossip.on_peer_disconnected(p);
                    if !ctx.swarm.is_connected(&p) {
                        self.events.push_back(NodeEvent::PeerDisconnected { peer: p });
                    }
                }
            }
            SwarmEvent::DialFailed { cid, peer, reason } => {
                self.rpc.on_conn_closed(cid);
                if let Some(p) = peer {
                    // Queries waiting on this dial fail over to the
                    // next-closest candidate instead of stalling; fetch
                    // sessions drop the unreachable provider.
                    let mut ctx = Ctx::new(&mut self.swarm, net);
                    self.kad.on_peer_unreachable(&mut ctx, p);
                    self.bitswap.on_peer_unreachable(&mut ctx, p);
                }
                crate::log_debug!("dial failed: {reason}");
            }
            SwarmEvent::InboundStream { .. } => {
                // Streams materialize on first message; nothing to do here.
            }
            SwarmEvent::StreamMsg { cid, stream, msg } => {
                self.dispatch_stream_msg(net, cid, stream, msg);
            }
            SwarmEvent::StreamFinished { .. } | SwarmEvent::StreamReset { .. } => {}
            SwarmEvent::ObservedAddr { addr } => {
                self.events.push_back(NodeEvent::ObservedAddr { addr });
            }
            SwarmEvent::PunchResult { peer, success, .. } => {
                self.events.push_back(NodeEvent::PunchResult { peer, success });
            }
        }
    }

    fn dispatch_stream_msg(&mut self, net: &mut Net, cid: u64, stream: u64, msg: crate::util::Buf) {
        let Some(peer) = self.swarm.connection_peer(cid) else { return };
        let proto = self
            .swarm
            .stream_proto(cid, stream)
            .unwrap_or_default();
        let remote_host = match self.swarm.connection_path(cid) {
            Some(crate::swarm::Path::Direct(a)) => a.host,
            _ => 0,
        };
        let mut ctx = Ctx::new(&mut self.swarm, net);
        let res: Result<()> = match proto.as_str() {
            KAD_PROTO => {
                // Responder vs requester: if we have a pending query using
                // this stream the message is a reply; otherwise serve it.
                // handle_response ignores non-replies and vice versa.
                self.kad.handle_response(&mut ctx, cid, stream, &msg);
                self.kad.handle_request(&mut ctx, peer, cid, stream, &msg)
            }
            BITSWAP_PROTO => {
                self.bitswap
                    .handle_msg(&mut ctx, &mut self.blockstore, peer, cid, stream, &msg)
            }
            GOSSIP_PROTO => self.gossip.handle_msg(&mut ctx, peer, cid, stream, &msg),
            RPC_PROTO => self.rpc.handle_unary_msg(&mut ctx, peer, cid, stream, &msg),
            RPC_STREAM_PROTO => self
                .rpc
                .handle_stream_msg(&mut ctx, peer, cid, stream, &msg),
            PING_PROTO => {
                self.ping.handle_msg(&mut ctx, cid, stream, &msg);
                Ok(())
            }
            IDENTIFY_PROTO => self.identify.handle_msg(&mut ctx, peer, &msg),
            AUTONAT_PROTO => self.autonat.handle_msg(&mut ctx, &msg),
            RENDEZVOUS_PROTO => {
                self.rendezvous
                    .handle_msg(&mut ctx, peer, remote_host, cid, stream, &msg)
            }
            DCUTR_PROTO => self.dcutr.handle_msg(&mut ctx, peer, cid, stream, &msg),
            // CRDT anti-entropy (see crdt_sync below).
            CRDT_PROTO => self.handle_crdt_msg(net, peer, cid, stream, &msg),
            other => {
                crate::log_debug!("unrouted protocol {other:?}");
                Ok(())
            }
        };
        if let Err(e) = res {
            crate::log_debug!("protocol {proto} error from {peer}: {e}");
        }
    }

    // ------------------------------------------------------------------
    // CRDT anti-entropy
    // ------------------------------------------------------------------

    /// Push our full CRDT state to a peer (simple anti-entropy; the digest
    /// comparison in `crdt_converged` verifies convergence).
    pub fn crdt_sync_with(&mut self, net: &mut Net, peer: &PeerId) -> Result<()> {
        let state = self.crdt.encode();
        let mut ctx = Ctx::new(&mut self.swarm, net);
        // Full-state anti-entropy can be large: background class.
        let (cid, stream) =
            ctx.open_stream_class(peer, CRDT_PROTO, crate::transport::TrafficClass::Bulk)?;
        ctx.send(cid, stream, &state)?;
        ctx.finish(cid, stream);
        Ok(())
    }

    fn handle_crdt_msg(
        &mut self,
        _net: &mut Net,
        _peer: PeerId,
        _cid: u64,
        _stream: u64,
        msg: &[u8],
    ) -> Result<()> {
        let other = CrdtStore::decode(msg)?;
        self.crdt.merge(&other)?;
        Ok(())
    }
}

/// CRDT anti-entropy protocol id.
pub const CRDT_PROTO: &str = "/lattica/crdt/1";

impl Endpoint for LatticaNode {
    fn on_datagram(&mut self, net: &mut Net, from: SimAddr, to: SimAddr, payload: Vec<u8>) {
        // AutoNAT probe datagrams are not transport packets.
        if payload.len() == 16 && payload.starts_with(PROBE_MAGIC) {
            self.autonat.handle_probe_datagram(&payload);
            self.pump(net);
            return;
        }
        self.swarm.handle_datagram(net, from, to, payload);
        self.pump(net);
    }

    fn on_timer(&mut self, net: &mut Net, token: u64) {
        match token {
            TIMER_SWARM_TICK => self.swarm.on_timer(net, token),
            TIMER_PROTO_TICK => {
                self.tick_armed = false;
                {
                    let mut ctx = Ctx::new(&mut self.swarm, net);
                    self.kad.tick(&mut ctx);
                    self.bitswap.tick(&mut ctx, &self.blockstore);
                    self.gossip.tick(&mut ctx);
                    self.rpc.tick(&mut ctx);
                    self.relay_mgr.tick(
                        &mut ctx,
                        &mut self.gossip,
                        &mut self.autonat,
                        self.cfg.relay_autopromote,
                    );
                }
                self.autonat.tick(net.now());
                self.dcutr.tick(net.now());
                self.arm_proto_tick(net);
            }
            _ => {}
        }
        self.pump(net);
    }
}

/// Run the world until `pred` is true or `timeout` virtual time passes.
/// Convenience for tests/examples. Returns whether the predicate held.
pub fn run_until<F: FnMut() -> bool>(world: &mut World, timeout: Time, mut pred: F) -> bool {
    let start = world.net.now();
    while world.net.now() < start + timeout {
        if pred() {
            return true;
        }
        world.run_for(20 * MILLI);
    }
    pred()
}

/// Convenience: virtual-time seconds.
pub fn secs(s: u64) -> Time {
    s * SECOND
}
