//! Node configuration: role flags + a TOML-subset file parser so
//! deployments can be described declaratively (the launcher in `main.rs`
//! reads these).
//!
//! Supported syntax: `key = value` lines, `[section]` headers (flattened
//! to `section.key`), `#` comments, string/integer/bool/float values.

use crate::multiaddr::Proto;
use crate::transport::CcAlgorithm;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Role/behaviour configuration for one node.
#[derive(Clone, Debug)]
pub struct NodeConfig {
    /// Deterministic identity seed.
    pub seed: u64,
    /// Listen port.
    pub port: u16,
    /// Preferred transport.
    pub proto: Proto,
    /// Congestion control for this node's connections (per-role: a
    /// trainer pushing checkpoints across continents wants CUBIC; a
    /// config can pin "newreno" or the "fixed" seed baseline).
    pub cc: CcAlgorithm,
    /// Serve as a circuit relay.
    pub relay_enabled: bool,
    /// Serve as a rendezvous registry.
    pub rendezvous_server: bool,
    /// Swarm-mode blob sync: discover extra providers on the DHT while a
    /// fetch runs and announce ourselves as a seeder of blobs we are
    /// downloading. Off = parameter-server behaviour (fetch only from the
    /// providers the caller names, never re-serve announcements).
    pub swarm_sync: bool,
    /// Compact control plane: range-coded Bitswap chunk sets over
    /// manifest indexes, batched HAVE pushes and gossip lazy push
    /// (IHAVE/IWANT). Off = legacy full-CID / full-payload encodings —
    /// the A/B baseline for the control-ratio bench (see DESIGN.md
    /// §Control-plane compression). Either side of a conversation may
    /// run legacy: the wire format is forward- and backward-compatible.
    pub compact_control: bool,
    /// Self-promote to relay duty when the known relay tier saturates
    /// (requires an AutoNAT-confirmed public address).
    pub relay_autopromote: bool,
    /// Relay capacity knobs, forwarded into the swarm when relaying:
    /// max concurrent circuits / reservations and the forwarding egress
    /// budget in bytes/s (0 = unlimited).
    pub relay_max_circuits: usize,
    pub relay_max_reservations: usize,
    pub relay_egress_bps: u64,
    /// Default admission rate (requests/second) installed for services
    /// registered without their own [`crate::rpc::AdmissionPolicy`].
    /// 0 = no node-wide admission control (opt-in per service).
    pub admission_rate: f64,
    /// Bucket depth for the node-wide default admission policy.
    pub admission_burst: f64,
    /// Human label for logs/reports.
    pub label: String,
}

impl Default for NodeConfig {
    fn default() -> Self {
        NodeConfig {
            seed: 1,
            port: 4001,
            proto: Proto::QuicLike,
            cc: CcAlgorithm::Cubic,
            relay_enabled: false,
            rendezvous_server: false,
            swarm_sync: true,
            compact_control: true,
            relay_autopromote: false,
            relay_max_circuits: 1024,
            relay_max_reservations: 512,
            relay_egress_bps: 0,
            admission_rate: 0.0,
            admission_burst: 32.0,
            label: String::new(),
        }
    }
}

impl NodeConfig {
    pub fn with_seed(seed: u64) -> NodeConfig {
        NodeConfig {
            seed,
            ..NodeConfig::default()
        }
    }

    pub fn relay(seed: u64) -> NodeConfig {
        NodeConfig {
            seed,
            relay_enabled: true,
            rendezvous_server: true,
            label: "relay".into(),
            ..NodeConfig::default()
        }
    }

    /// Build from a parsed config table (prefix e.g. "node").
    pub fn from_table(t: &BTreeMap<String, ConfigValue>, prefix: &str) -> NodeConfig {
        let get = |k: &str| t.get(&format!("{prefix}.{k}"));
        let mut c = NodeConfig::default();
        if let Some(v) = get("seed").and_then(|v| v.as_int()) {
            c.seed = v as u64;
        }
        if let Some(v) = get("port").and_then(|v| v.as_int()) {
            c.port = v as u16;
        }
        if let Some(v) = get("relay").and_then(|v| v.as_bool()) {
            c.relay_enabled = v;
        }
        if let Some(v) = get("rendezvous").and_then(|v| v.as_bool()) {
            c.rendezvous_server = v;
        }
        if let Some(v) = get("swarm_sync").and_then(|v| v.as_bool()) {
            c.swarm_sync = v;
        }
        if let Some(v) = get("compact_control").and_then(|v| v.as_bool()) {
            c.compact_control = v;
        }
        if let Some(v) = get("relay_autopromote").and_then(|v| v.as_bool()) {
            c.relay_autopromote = v;
        }
        if let Some(v) = get("relay_max_circuits").and_then(|v| v.as_int()) {
            c.relay_max_circuits = v.max(0) as usize;
        }
        if let Some(v) = get("relay_max_reservations").and_then(|v| v.as_int()) {
            c.relay_max_reservations = v.max(0) as usize;
        }
        if let Some(v) = get("relay_egress_bps").and_then(|v| v.as_int()) {
            c.relay_egress_bps = v.max(0) as u64;
        }
        if let Some(v) = get("admission_rate").and_then(|v| v.as_float()) {
            c.admission_rate = v.max(0.0);
        }
        if let Some(v) = get("admission_burst").and_then(|v| v.as_float()) {
            c.admission_burst = v.max(1.0);
        }
        if let Some(v) = get("label").and_then(|v| v.as_str()) {
            c.label = v.to_string();
        }
        if let Some(v) = get("transport").and_then(|v| v.as_str()) {
            c.proto = if v == "tcp" { Proto::TcpLike } else { Proto::QuicLike };
        }
        if let Some(v) = get("cc").and_then(|v| v.as_str()) {
            if let Some(algo) = CcAlgorithm::parse(v) {
                c.cc = algo;
            }
        }
        c
    }
}

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl ConfigValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            ConfigValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            ConfigValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            ConfigValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            ConfigValue::Float(f) => Some(*f),
            ConfigValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

/// Parse TOML-subset text into a flat `section.key → value` table.
pub fn parse_config(text: &str) -> Result<BTreeMap<String, ConfigValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = inner.trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected key = value", lineno + 1);
        };
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let v = v.trim();
        let value = if let Some(s) = v.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
            ConfigValue::Str(s.to_string())
        } else if v == "true" || v == "false" {
            ConfigValue::Bool(v == "true")
        } else if let Ok(i) = v.parse::<i64>() {
            ConfigValue::Int(i)
        } else if let Ok(f) = v.parse::<f64>() {
            ConfigValue::Float(f)
        } else {
            ConfigValue::Str(v.to_string())
        };
        out.insert(key, value);
    }
    Ok(out)
}

/// Load a config file.
pub fn load_config(path: &str) -> Result<BTreeMap<String, ConfigValue>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    parse_config(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = r#"
# deployment
global_seed = 42

[node]
seed = 7
port = 4002
relay = true
cc = "newreno"
label = "edge-1"  # trailing comment
lr = 0.5
"#;
        let t = parse_config(text).unwrap();
        assert_eq!(t["global_seed"], ConfigValue::Int(42));
        assert_eq!(t["node.seed"], ConfigValue::Int(7));
        assert_eq!(t["node.relay"], ConfigValue::Bool(true));
        assert_eq!(t["node.label"], ConfigValue::Str("edge-1".into()));
        assert_eq!(t["node.lr"], ConfigValue::Float(0.5));

        let c = NodeConfig::from_table(&t, "node");
        assert_eq!(c.seed, 7);
        assert_eq!(c.port, 4002);
        assert!(c.relay_enabled);
        assert_eq!(c.label, "edge-1");
        assert_eq!(c.cc, CcAlgorithm::NewReno);
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(parse_config("not a kv line").is_err());
    }

    #[test]
    fn defaults_sane() {
        let c = NodeConfig::default();
        assert_eq!(c.port, 4001);
        assert!(!c.relay_enabled);
        assert!(c.swarm_sync);
        assert!(c.compact_control);
        assert_eq!(c.cc, CcAlgorithm::Cubic);
        let r = NodeConfig::relay(9);
        assert!(r.relay_enabled && r.rendezvous_server);
    }
}
