//! Relay autoscaling: a gossip-advertised relay directory, load-aware
//! reservation maintenance, and self-promotion.
//!
//! Relays periodically publish a [`RelayAd`] (address + utilization 0–100)
//! on the `lattica:relay-ads` gossip topic. Every node subscribes and
//! keeps the live ads in a directory. NATted nodes maintain a couple of
//! reservations on the least-loaded relays (dialing them as needed and
//! refreshing before the reservation TTL lapses); well-reachable nodes
//! with `relay_autopromote` watch the directory and enable relay duty on
//! themselves when the whole advertised tier is saturated — the relay
//! pool scales with demand instead of being a fixed set of seed nodes.

use crate::identity::PeerId;
use crate::multiaddr::{Multiaddr, Proto, SimAddr};
use crate::netsim::{Time, SECOND};
use crate::protocols::autonat::{Autonat, NatStatus};
use crate::protocols::gossip::Gossip;
use crate::protocols::Ctx;
use crate::swarm::RESERVATION_TTL;
use crate::wire::{Message, PbReader, PbWriter};
use anyhow::Result;
use std::collections::BTreeMap;

/// Gossip topic relay ads travel on.
pub const RELAY_ADS_TOPIC: &str = "lattica:relay-ads";
/// How often a relay re-advertises itself.
pub const AD_INTERVAL: Time = 2 * SECOND;
/// Ads older than this are dropped from the directory.
pub const AD_TTL: Time = 10 * SECOND;
/// How many relay reservations a NATted node maintains (one live + one
/// backup for mid-stream failover).
pub const TARGET_RESERVATIONS: usize = 2;
/// Minimum utilization across every advertised relay before a
/// `relay_autopromote` node enables relay duty on itself.
pub const PROMOTE_LOAD: u32 = 70;
/// Spacing of AutoNAT dial-back probes while reachability is unknown.
const PROBE_INTERVAL: Time = 2 * SECOND;

/// One relay's gossip advertisement.
#[derive(Clone, Debug, PartialEq)]
pub struct RelayAd {
    pub peer: PeerId,
    pub host: u32,
    pub port: u16,
    /// Advertised utilization 0–100 (see `Swarm::relay_utilization`).
    pub load: u32,
}

impl Message for RelayAd {
    fn encode_to(&self, w: &mut PbWriter) {
        w.bytes(1, self.peer.as_bytes());
        w.uint(2, self.host as u64);
        w.uint(3, self.port as u64);
        w.uint(4, self.load as u64);
    }

    fn decode(buf: &[u8]) -> Result<RelayAd> {
        let mut peer = None;
        let (mut host, mut port, mut load) = (0u32, 0u64, 0u32);
        PbReader::new(buf).for_each(|f| {
            match f.number {
                1 => {
                    let b = f.as_bytes()?;
                    anyhow::ensure!(b.len() == 32, "bad peer id length");
                    let mut d = [0u8; 32];
                    d.copy_from_slice(b);
                    peer = Some(PeerId(d));
                }
                2 => host = f.as_u64() as u32,
                3 => port = f.as_u64(),
                4 => load = f.as_u64() as u32,
                _ => {}
            }
            Ok(())
        })?;
        anyhow::ensure!(port <= u16::MAX as u64, "relay ad port {port} out of range");
        Ok(RelayAd {
            peer: peer.ok_or_else(|| anyhow::anyhow!("relay ad missing peer"))?,
            host,
            port: port as u16,
            load: load.min(100),
        })
    }
}

impl RelayAd {
    pub fn multiaddr(&self) -> Multiaddr {
        Multiaddr::direct(SimAddr::new(self.host, self.port), Proto::QuicLike).with_peer(self.peer)
    }
}

/// Per-node relay autoscaling state. Driven from the protocol tick.
pub struct RelayManager {
    /// Live ads by relay peer (BTreeMap: deterministic selection order).
    ads: BTreeMap<PeerId, (RelayAd, Time)>,
    last_ad: Time,
    last_probe: Time,
    last_refresh: Time,
    /// Set once self-promotion fired (diagnostic; promotion is one-way).
    pub promoted: bool,
}

impl Default for RelayManager {
    fn default() -> Self {
        Self::new()
    }
}

impl RelayManager {
    pub fn new() -> RelayManager {
        RelayManager {
            ads: BTreeMap::new(),
            last_ad: 0,
            last_probe: 0,
            last_refresh: 0,
            promoted: false,
        }
    }

    /// Ingest a relay ad received on [`RELAY_ADS_TOPIC`].
    pub fn handle_ad(&mut self, now: Time, data: &[u8]) -> Result<()> {
        let ad = RelayAd::decode(data)?;
        self.ads.insert(ad.peer, (ad, now + AD_TTL));
        Ok(())
    }

    fn expire(&mut self, now: Time) {
        self.ads.retain(|_, (_, exp)| *exp > now);
    }

    /// Live ads, least-loaded first (ties broken by peer id).
    pub fn relays_by_load(&self) -> Vec<RelayAd> {
        let mut v: Vec<RelayAd> = self.ads.values().map(|(ad, _)| ad.clone()).collect();
        v.sort_by_key(|ad| (ad.load, ad.peer.0));
        v
    }

    /// Lowest advertised utilization across the live relay tier.
    pub fn min_load(&self) -> Option<u32> {
        self.ads.values().map(|(ad, _)| ad.load).min()
    }

    pub fn known_relays(&self) -> usize {
        self.ads.len()
    }

    /// Periodic drive. Relays advertise; NATted clients probe/reserve;
    /// public nodes with `autopromote` watch for tier saturation.
    pub fn tick(&mut self, ctx: &mut Ctx, gossip: &mut Gossip, autonat: &mut Autonat, autopromote: bool) {
        let now = ctx.now();
        self.expire(now);

        if ctx.swarm.cfg.relay_enabled {
            if now.saturating_sub(self.last_ad) >= AD_INTERVAL || self.last_ad == 0 {
                self.last_ad = now;
                let ad = RelayAd {
                    peer: ctx.local_peer(),
                    host: ctx.swarm.local_addr.host,
                    port: ctx.swarm.local_addr.port,
                    load: ctx.swarm.relay_utilization(now),
                };
                self.ads.insert(ad.peer, (ad.clone(), now + AD_TTL));
                gossip.publish(ctx, RELAY_ADS_TOPIC, ad.encode());
            }
            return; // relays serve, they don't reserve
        }

        match autonat.status {
            NatStatus::Unknown => {
                // Find out whether we need a relay at all.
                if now.saturating_sub(self.last_probe) >= PROBE_INTERVAL {
                    self.last_probe = now;
                    if let Some(p) = ctx.swarm.connected_peers().first().copied() {
                        let _ = autonat.probe(ctx, &p);
                    }
                }
            }
            NatStatus::Public => {
                // Tier saturated and we're reachable: become a relay. The
                // next tick publishes our first ad.
                if autopromote
                    && !self.promoted
                    && !self.ads.is_empty()
                    && self.min_load().map_or(false, |l| l >= PROMOTE_LOAD)
                {
                    self.promoted = true;
                    ctx.swarm.set_relay_enabled(true);
                    crate::log_debug!("relay tier saturated: self-promoting to relay duty");
                }
            }
            NatStatus::Private => {
                let held = ctx.swarm.reserved_relays();
                if held.len() < TARGET_RESERVATIONS {
                    let want = TARGET_RESERVATIONS - held.len();
                    let mut picked = 0;
                    for ad in self.relays_by_load() {
                        if picked >= want {
                            break;
                        }
                        if held.contains(&ad.peer) || ad.load >= 100 {
                            continue;
                        }
                        if ctx.swarm.is_connected(&ad.peer) {
                            if ctx.swarm.relay_reserve(ctx.net, &ad.peer).is_ok() {
                                picked += 1;
                            }
                        } else {
                            // Reserve on the next tick, once connected.
                            let _ = ctx.dial(&ad.multiaddr());
                            picked += 1;
                        }
                    }
                }
                // Refresh held reservations well before the relay-side TTL.
                if now.saturating_sub(self.last_refresh) >= RESERVATION_TTL / 2 {
                    self.last_refresh = now;
                    for p in &held {
                        let _ = ctx.swarm.relay_reserve(ctx.net, p);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ad_roundtrip() {
        let ad = RelayAd {
            peer: PeerId([7; 32]),
            host: 42,
            port: 4001,
            load: 63,
        };
        assert_eq!(RelayAd::decode(&ad.encode()).unwrap(), ad);
    }

    #[test]
    fn ad_oversized_port_rejected() {
        let mut w = PbWriter::new();
        w.bytes(1, &[1u8; 32]);
        w.uint(3, 70_000);
        assert!(RelayAd::decode(&w.finish()).is_err());
    }

    #[test]
    fn directory_orders_by_load_and_expires() {
        let mut m = RelayManager::new();
        let mk = |seed: u8, load: u32| RelayAd {
            peer: PeerId([seed; 32]),
            host: seed as u32,
            port: 4001,
            load,
        };
        m.handle_ad(0, &mk(1, 80).encode()).unwrap();
        m.handle_ad(0, &mk(2, 10).encode()).unwrap();
        m.handle_ad(5 * SECOND, &mk(3, 50).encode()).unwrap();
        let order: Vec<u32> = m.relays_by_load().iter().map(|a| a.load).collect();
        assert_eq!(order, vec![10, 50, 80]);
        assert_eq!(m.min_load(), Some(10));
        // First two ads expire at AD_TTL; the later one survives.
        m.expire(AD_TTL + 1);
        assert_eq!(m.known_relays(), 1);
        assert_eq!(m.min_load(), Some(50));
    }
}
