//! Peer identity: keypairs and `PeerId`s.
//!
//! A peer's identity is its x25519 static keypair; the [`PeerId`] is the
//! SHA-256 multihash of the public key (mirroring libp2p, where the PeerId
//! is a multihash of the identity key). The Noise handshake authenticates
//! the static key, so a connection is bound to a PeerId by construction.
//!
//! Signed records (used by the DHT and rendezvous for provider/registration
//! records) use an HMAC-of-DH construction: the record is authenticated to
//! any verifier holding the signer's public key via a per-verifier MAC. For
//! gossip (one-to-many) we include a hash commitment chain instead; the
//! security notes in DESIGN.md §3 cover why this preserves the evaluated
//! behaviour (integrity + attribution among connected, handshaked peers).

use crate::crypto::sha256::Sha256;
use crate::crypto::{PublicKey, StaticSecret};
use crate::util::hex;
use anyhow::Result;
use std::fmt;

/// SHA-256 multihash prefix: code 0x12, length 32.
const MULTIHASH_SHA256: [u8; 2] = [0x12, 0x20];

/// A peer identifier: multihash of the identity public key.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PeerId(pub [u8; 32]);

impl PeerId {
    pub fn from_public_key(pk: &PublicKey) -> PeerId {
        let mut h = Sha256::new();
        h.update(pk.as_bytes());
        PeerId(h.finalize().into())
    }

    /// Raw digest bytes (used as the Kademlia key).
    pub fn as_bytes(&self) -> &[u8; 32] {
        &self.0
    }

    /// Multihash encoding (0x12 0x20 || digest).
    pub fn to_multihash(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(34);
        v.extend_from_slice(&MULTIHASH_SHA256);
        v.extend_from_slice(&self.0);
        v
    }

    pub fn from_multihash(b: &[u8]) -> Result<PeerId> {
        anyhow::ensure!(b.len() == 34, "peer multihash must be 34 bytes");
        anyhow::ensure!(b[..2] == MULTIHASH_SHA256, "unsupported multihash code");
        let mut d = [0u8; 32];
        d.copy_from_slice(&b[2..]);
        Ok(PeerId(d))
    }

    /// XOR distance to another id (Kademlia metric).
    pub fn distance(&self, other: &PeerId) -> [u8; 32] {
        let mut d = [0u8; 32];
        for i in 0..32 {
            d[i] = self.0[i] ^ other.0[i];
        }
        d
    }

    /// Index of the highest differing bit (255 = most significant); None if equal.
    pub fn bucket_index(&self, other: &PeerId) -> Option<usize> {
        let d = self.distance(other);
        for (byte, &v) in d.iter().enumerate() {
            if v != 0 {
                return Some(255 - (byte * 8 + v.leading_zeros() as usize));
            }
        }
        None
    }
}

impl fmt::Debug for PeerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PeerId({})", hex::encode_prefix(&self.0, 8))
    }
}

impl fmt::Display for PeerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", hex::encode_prefix(&self.0, 12))
    }
}

/// A peer's long-lived identity keypair.
#[derive(Clone)]
pub struct Keypair {
    secret: StaticSecret,
    public: PublicKey,
    peer_id: PeerId,
}

impl Keypair {
    /// Generate from the simulation RNG.
    pub fn generate(rng: &mut crate::util::Rng) -> Keypair {
        let secret = StaticSecret::generate(rng);
        let public = secret.public_key();
        let peer_id = PeerId::from_public_key(&public);
        Keypair {
            secret,
            public,
            peer_id,
        }
    }

    /// Deterministic keypair from a seed (tests, reproducible deployments).
    pub fn from_seed(seed: u64) -> Keypair {
        let mut rng = crate::util::Rng::new(seed ^ 0x1DE4_7177_5EED_0001);
        Keypair::generate(&mut rng)
    }

    pub fn peer_id(&self) -> PeerId {
        self.peer_id
    }

    pub fn public(&self) -> PublicKey {
        self.public
    }

    pub fn secret(&self) -> &StaticSecret {
        &self.secret
    }

    /// MAC-style record authentication between handshaked peers: the key is
    /// the DH shared secret, so only the two endpoints can produce/verify.
    pub fn record_mac(&self, verifier: &PublicKey, record: &[u8]) -> [u8; 32] {
        let shared = self.secret.diffie_hellman(verifier);
        crate::crypto::hkdf::hmac_sha256(&shared, record)
    }

    /// Verify a record MAC produced by `signer` for us.
    pub fn verify_record_mac(
        &self,
        signer: &PublicKey,
        record: &[u8],
        mac: &[u8; 32],
    ) -> bool {
        let shared = self.secret.diffie_hellman(signer);
        let want = crate::crypto::hkdf::hmac_sha256(&shared, record);
        crate::util::bytes::ct_eq(&want, mac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn peer_id_deterministic() {
        let k1 = Keypair::from_seed(7);
        let k2 = Keypair::from_seed(7);
        assert_eq!(k1.peer_id(), k2.peer_id());
        let k3 = Keypair::from_seed(8);
        assert_ne!(k1.peer_id(), k3.peer_id());
    }

    #[test]
    fn multihash_roundtrip() {
        let k = Keypair::from_seed(1);
        let mh = k.peer_id().to_multihash();
        assert_eq!(mh.len(), 34);
        assert_eq!(PeerId::from_multihash(&mh).unwrap(), k.peer_id());
        assert!(PeerId::from_multihash(&mh[..33]).is_err());
    }

    #[test]
    fn xor_distance_properties() {
        let a = Keypair::from_seed(1).peer_id();
        let b = Keypair::from_seed(2).peer_id();
        // d(a,a) = 0
        assert_eq!(a.distance(&a), [0u8; 32]);
        // symmetry
        assert_eq!(a.distance(&b), b.distance(&a));
        // bucket index in range
        let idx = a.bucket_index(&b).unwrap();
        assert!(idx < 256);
        assert_eq!(a.bucket_index(&a), None);
    }

    #[test]
    fn record_mac_verifies() {
        let mut rng = Rng::new(9);
        let alice = Keypair::generate(&mut rng);
        let bob = Keypair::generate(&mut rng);
        let mac = alice.record_mac(&bob.public(), b"provider-record");
        assert!(bob.verify_record_mac(&alice.public(), b"provider-record", &mac));
        assert!(!bob.verify_record_mac(&alice.public(), b"tampered", &mac));
        let carol = Keypair::generate(&mut rng);
        assert!(!carol.verify_record_mac(&alice.public(), b"provider-record", &mac));
    }
}

impl Default for PeerId {
    fn default() -> Self {
        PeerId([0u8; 32])
    }
}
