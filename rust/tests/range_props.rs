//! Property suite for the control-plane range codec: seeded random index
//! sets asserting encode/decode identity, iterator monotonicity, and
//! membership agreement with a reference `BTreeSet`, across empty,
//! singleton, dense and sparse shapes. Failures shrink the op count and
//! panic with a replay line, like `crdt_props`.

use std::collections::BTreeSet;

use lattica::util::Rng;
use lattica::wire::{BloomDigest, RangeSet};

/// Draw one value from the shape's universe. Dense shapes pack values
/// into a small window (exercising run merging); sparse shapes spread
/// them over the u64 line (exercising large gap varints).
fn draw(rng: &mut Rng, shape: usize, ops: usize) -> u64 {
    match shape {
        // Dense: values land in [0, ops) so most inserts extend a run.
        0 => rng.gen_range(ops.max(1) as u64),
        // Clustered: a few windows of nearby values.
        1 => rng.gen_range(8) * 1_000 + rng.gen_range(16),
        // Sparse: anywhere on the u64 line (keeps headroom below
        // u64::MAX so run ends cannot overflow).
        _ => rng.gen_range(u64::MAX / 2),
    }
}

/// One seeded case over all shapes. Returns a failure description so the
/// caller can shrink and print a replay.
fn range_props_case(seed: u64, ops: usize) -> Result<(), String> {
    for shape in 0..3usize {
        let mut rng = Rng::new(seed ^ ((shape as u64) << 32));
        let mut set = RangeSet::new();
        let mut reference = BTreeSet::new();
        for _ in 0..ops {
            let v = draw(&mut rng, shape, ops);
            set.insert(v);
            reference.insert(v);
        }

        // Cardinality and membership agree with the reference set.
        if set.len() != reference.len() as u64 {
            return Err(format!(
                "shape {shape}: len {} != reference {}",
                set.len(),
                reference.len()
            ));
        }
        for &v in &reference {
            if !set.contains(v) {
                return Err(format!("shape {shape}: lost inserted value {v}"));
            }
        }
        // Probe around each value: membership must match exactly.
        for &v in reference.iter().take(64) {
            for probe in [v.wrapping_sub(1), v + 1] {
                if set.contains(probe) != reference.contains(&probe) {
                    return Err(format!(
                        "shape {shape}: membership disagrees at {probe}"
                    ));
                }
            }
        }

        // Iteration is ascending, duplicate-free, and equals the
        // reference order exactly.
        let walked: Vec<u64> = set.iter().take(reference.len()).collect();
        let expect: Vec<u64> = reference.iter().copied().collect();
        if walked != expect {
            return Err(format!("shape {shape}: iter order diverged"));
        }
        if walked.windows(2).any(|w| w[0] >= w[1]) {
            return Err(format!("shape {shape}: iter not strictly ascending"));
        }

        // Encode/decode identity, and the length accessor matches the
        // actual encoding.
        let wire = set.encode();
        if wire.len() != set.encoded_len() {
            return Err(format!(
                "shape {shape}: encoded_len {} != wire {}",
                set.encoded_len(),
                wire.len()
            ));
        }
        let back = RangeSet::decode(&wire)
            .map_err(|e| format!("shape {shape}: decode failed: {e}"))?;
        if back != set {
            return Err(format!("shape {shape}: decode(encode(s)) != s"));
        }

        // FromIterator over a shuffled order builds the identical set.
        let mut shuffled = expect.clone();
        for i in (1..shuffled.len()).rev() {
            shuffled.swap(i, rng.gen_index(i + 1));
        }
        let rebuilt: RangeSet = shuffled.into_iter().collect();
        if rebuilt != set {
            return Err(format!("shape {shape}: insertion order changed the set"));
        }

        // Bloom companion: whatever went into the digest must be found.
        let mut bloom = BloomDigest::new();
        for &v in reference.iter().take(128) {
            bloom.insert(&v.to_be_bytes());
        }
        for &v in reference.iter().take(128) {
            if !bloom.contains(&v.to_be_bytes()) {
                return Err(format!("shape {shape}: bloom false negative for {v}"));
            }
        }
    }
    Ok(())
}

#[test]
fn range_codec_laws_hold_across_seeds() {
    // Many seeded shapes; on failure, shrink the op count for the failing
    // seed so the panic carries a minimal replay
    // (`range_props_case(seed, ops)`).
    for seed in 1..=40u64 {
        let ops = 300;
        if let Err(err) = range_props_case(seed, ops) {
            let mut min_ops = ops;
            while min_ops > 1 && range_props_case(seed, min_ops - 1).is_err() {
                min_ops -= 1;
            }
            panic!("range codec violation: {err}\n  replay: range_props_case({seed}, {min_ops})");
        }
    }
}

#[test]
fn range_codec_edge_shapes() {
    // Empty: no bytes on the wire, nothing on iteration.
    let empty = RangeSet::new();
    assert!(empty.is_empty());
    assert!(empty.encode().is_empty());
    assert_eq!(RangeSet::decode(&[]).unwrap(), empty);

    // Singleton: one gap varint + one run varint.
    let one: RangeSet = [42u64].into_iter().collect();
    assert_eq!(one.len(), 1);
    assert!(one.contains(42) && !one.contains(41) && !one.contains(43));
    assert_eq!(RangeSet::decode(&one.encode()).unwrap(), one);

    // Fully dense: one run regardless of size.
    let dense: RangeSet = (0u64..10_000).collect();
    assert_eq!(dense.len(), 10_000);
    assert!(dense.encode().len() <= 4, "dense run must stay tiny");

    // Maximally sparse: every other index; the worst case still decodes
    // to the identical set.
    let sparse: RangeSet = (0u64..2_000).map(|i| i * 2).collect();
    assert_eq!(sparse.ranges().len(), 2_000);
    assert_eq!(RangeSet::decode(&sparse.encode()).unwrap(), sparse);
}
