//! Swarm model-distribution suite: delta checkpoints, rarest-first
//! multi-peer fetch, duplicate-suppression accounting, and the seeded
//! 30-node NAT-mixed end-to-end scenario from the acceptance criteria.
//!
//! Everything here is seeded and deterministic; the heavyweight 30-node
//! scenario is ignored under debug builds and runs in CI's release pass
//! (the same gating as `dht_churn`'s 200-node scenario).

use lattica::content::{Chunking, DagManifest};
use lattica::netsim::link::PathProfile;
use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, MILLI, SECOND};
use lattica::node::{run_until, LatticaNode, NodeConfig};
use lattica::protocols::Ctx;
use lattica::scenarios::{model_sync_scenario, ModelSyncConfig, SyncMode};
use lattica::util::Rng;
use lattica::wire::Message;

// ---------------------------------------------------------------------------
// Re-stripe accounting: a slow (not dead) provider answering after the
// WANT_TIMEOUT re-stripe must not double-count bytes in the ledger or
// cause a second blockstore write.
// ---------------------------------------------------------------------------

#[test]
fn slow_provider_after_restripe_does_not_double_count() {
    // F (region 0) fetches; S (region 1) is slow-but-alive: 700 ms one-way,
    // so its BLOCK answers land well after the 1 s want timeout; Q
    // (region 2) is fast.
    let mut t = TopologyBuilder::new(3);
    t.path(0, 1, PathProfile::new(700 * MILLI, 0, 0.0));
    t.path(0, 2, PathProfile::new(5 * MILLI, 0, 0.0));
    t.path(1, 2, PathProfile::new(5 * MILLI, 0, 0.0));
    let hf = t.public_host(0, LinkProfile::FIBER);
    let hs = t.public_host(1, LinkProfile::FIBER);
    let hq = t.public_host(2, LinkProfile::FIBER);
    let mut world = World::new(t.build(71));
    let f = LatticaNode::spawn(&mut world, hf, NodeConfig::with_seed(711));
    let s = LatticaNode::spawn(&mut world, hs, NodeConfig::with_seed(712));
    let q = LatticaNode::spawn(&mut world, hq, NodeConfig::with_seed(713));

    // Both providers hold the identical artifact (same root).
    let mut rng = Rng::new(72);
    let data = rng.gen_bytes(256 * 1024);
    let root_s = s
        .borrow_mut()
        .publish_blob(&mut world.net, "ckpt", 1, &data, 16 * 1024);
    let root_q = q
        .borrow_mut()
        .publish_blob(&mut world.net, "ckpt", 1, &data, 16 * 1024);
    assert_eq!(root_s, root_q, "same artifact must share one root");
    let root = root_s;

    // Pre-connect (the slow path needs a few RTTs to handshake) and seed
    // the manifest locally so the test isolates the chunk scheduler.
    let s_ma = s.borrow().listen_addr();
    let q_ma = q.borrow().listen_addr();
    f.borrow_mut().dial(&mut world.net, &s_ma).unwrap();
    f.borrow_mut().dial(&mut world.net, &q_ma).unwrap();
    let s_peer = s.borrow().peer_id();
    let q_peer = q.borrow().peer_id();
    let connected = run_until(&mut world, 20 * SECOND, || {
        let n = f.borrow();
        n.swarm.is_connected(&s_peer) && n.swarm.is_connected(&q_peer)
    });
    assert!(connected, "handshakes failed");
    let manifest = DagManifest::load(&s.borrow().blockstore, &root).unwrap();
    f.borrow_mut().blockstore.put(manifest.encode());

    // Fetch with the slow provider only; the fast one joins mid-session.
    let sid = f
        .borrow_mut()
        .fetch_manifest_chunks(&mut world.net, &root, vec![s_peer])
        .unwrap();
    world.run_for(300 * MILLI);
    {
        let mut n = f.borrow_mut();
        let LatticaNode { swarm, bitswap, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        bitswap.add_providers(&mut ctx, sid, vec![q_peer]);
    }
    let ok = run_until(&mut world, 30 * SECOND, || {
        let n = f.borrow();
        DagManifest::load(&n.blockstore, &root)
            .map(|m| m.is_complete(&n.blockstore))
            .unwrap_or(false)
    });
    assert!(ok, "fetch did not complete");
    // Let S's stale answers trickle in past the re-stripe.
    world.run_for(3 * SECOND);

    let n = f.borrow();
    let m = DagManifest::load(&n.blockstore, &root).unwrap();
    assert_eq!(m.assemble(&n.blockstore).unwrap(), data, "bytes diverged");
    // Exact ledger accounting: every chunk credited once, late duplicates
    // not credited at all.
    let received: u64 = n.bitswap.ledgers.values().map(|l| l.bytes_received).sum();
    assert_eq!(
        received,
        data.len() as u64,
        "ledger must credit each block exactly once"
    );
    assert!(
        n.bitswap.stats.duplicate_blocks >= 1,
        "the slow provider's late answer must surface as a duplicate"
    );
    assert_eq!(
        n.blockstore.stats.duplicate_puts, 0,
        "a late duplicate must not reach the blockstore"
    );
    // Local manifest put + one store per chunk, nothing else.
    assert_eq!(
        n.blockstore.stats.stores,
        1 + m.chunks.len() as u64,
        "every block written exactly once"
    );
}

// ---------------------------------------------------------------------------
// Small always-on swarm scenario (debug-friendly)
// ---------------------------------------------------------------------------

#[test]
fn swarm_delta_sync_small_mesh() {
    let mut out = model_sync_scenario(&ModelSyncConfig {
        replicas: 6,
        checkpoints: 2,
        blob_bytes: 512 * 1024,
        churn: 0.10,
        mode: SyncMode::Swarm,
        delta: true,
        nat_mixed: false,
        chunk_bytes: 0,
        compact_control: true,
        seed: 81,
        timeout_secs: 120,
    });
    assert!(out.completed, "small swarm sync timed out");
    assert!(out.all_identical, "replicas must assemble identical blobs");
    assert!(
        out.replica_bytes_served > 0,
        "replicas must re-serve chunks (seeder promotion)"
    );
    // v2 rides the delta: well under half of full demand moves.
    assert!(
        out.stats.fetched_fraction(1) < 0.5,
        "delta fetch moved {:.0}% of full demand",
        out.stats.fetched_fraction(1) * 100.0
    );
    assert_eq!(out.delta_bytes_announced.len(), 1);
    assert!(
        out.delta_bytes_announced[0] < 512 * 1024 / 2,
        "announced delta must be a fraction of the blob"
    );
    assert!(!out.stats.summary().is_empty());
}

/// Determinism: the scenario is a pure function of its config.
#[test]
fn model_sync_scenario_is_deterministic() {
    let cfg = ModelSyncConfig {
        replicas: 4,
        checkpoints: 2,
        blob_bytes: 256 * 1024,
        churn: 0.10,
        mode: SyncMode::Swarm,
        delta: true,
        nat_mixed: false,
        chunk_bytes: 0,
        compact_control: true,
        seed: 91,
        timeout_secs: 120,
    };
    let a = model_sync_scenario(&cfg);
    let b = model_sync_scenario(&cfg);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.all_identical, b.all_identical);
    assert_eq!(a.delta_bytes_announced, b.delta_bytes_announced);
    assert_eq!(
        a.stats.fetched_per_version, b.stats.fetched_per_version,
        "same config must move the same bytes"
    );
    assert_eq!(
        a.control, b.control,
        "same config must spend the same control-plane bytes"
    );
}

// ---------------------------------------------------------------------------
// The acceptance scenario: 30-node NAT-mixed mesh, 3 checkpoint versions
// with ~10% parameter churn. Heavy — ignored in debug builds, exercised
// by CI's release run.
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(debug_assertions, ignore = "release-mode scenario; run via CI or --include-ignored")]
fn swarm_distribution_30_nodes_nat_mixed() {
    let blob_bytes = 2 * 1024 * 1024;
    let mut out = model_sync_scenario(&ModelSyncConfig {
        replicas: 29,
        checkpoints: 3,
        blob_bytes,
        churn: 0.10,
        mode: SyncMode::Swarm,
        delta: true,
        nat_mixed: true,
        chunk_bytes: 0,
        compact_control: true,
        seed: 101,
        timeout_secs: 180,
    });
    assert!(out.completed, "30-node sync timed out");
    assert!(
        out.all_identical,
        "every replica must assemble byte-identical blobs"
    );
    // Delta versions (v2, v3) move <25% of the full-blob demand.
    for v in [1usize, 2] {
        let frac = out.stats.fetched_fraction(v);
        assert!(
            frac < 0.25,
            "delta fetch for v{} moved {:.0}% of full demand ({})",
            v + 1,
            frac * 100.0,
            out.stats.summary()
        );
    }
    // Trainer egress stays under 2x the blob per checkpoint: the swarm
    // (every replica a seeder) carries the fan-out, not the publisher.
    let egress_per_version = out.stats.egress_per_version.clone();
    for (v, &egress) in egress_per_version.iter().enumerate() {
        assert!(
            egress < 2 * blob_bytes as u64,
            "trainer egress for v{} is {} (>= 2x blob; {})",
            v + 1,
            egress,
            out.stats.summary()
        );
    }
    assert!(
        out.replica_bytes_served > out.stats.mean_egress() as u64,
        "replicas must out-serve the trainer"
    );
}

// ---------------------------------------------------------------------------
// Chunking interop: fixed and CDC publishes of the same data coexist.
// ---------------------------------------------------------------------------

#[test]
fn fixed_and_cdc_roots_differ_but_both_fetch() {
    let mut store = lattica::content::Blockstore::new();
    let mut rng = Rng::new(111);
    let data = rng.gen_bytes(200_000);
    let (root_fixed, mf) =
        DagManifest::publish_chunked(&mut store, "a", 1, &data, Chunking::Fixed(32 * 1024));
    let (root_cdc, mc) = DagManifest::publish_chunked(
        &mut store,
        "a",
        1,
        &data,
        Chunking::Cdc(lattica::content::CDC_CHECKPOINT),
    );
    assert_ne!(root_fixed, root_cdc);
    assert_eq!(mf.assemble(&store).unwrap(), data);
    assert_eq!(mc.assemble(&store).unwrap(), data);
}
