//! End-to-end integration tests over the full node stack: DHT bootstrap and
//! lookup, content publish/fetch via Bitswap, unary + streaming RPC, gossip
//! propagation, CRDT anti-entropy, and rendezvous discovery — all on the
//! deterministic simulator.

use lattica::content::Cid;
use lattica::identity::PeerId;
use lattica::multiaddr::Proto;
use lattica::netsim::nat::NatType;
use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::{run_until, LatticaNode, NodeConfig, NodeEvent};
use lattica::protocols::bitswap::BitswapEvent;
use lattica::protocols::kad::{KadEvent, PeerEntry, QueryKind};
use lattica::protocols::Ctx;
use lattica::rpc::{Outcome, Service, Status, StreamHandler, Stub};
use std::cell::RefCell;
use std::rc::Rc;

type Node = Rc<RefCell<LatticaNode>>;

/// N public nodes in one region, all bootstrapped through node 0.
fn mesh(n: usize, seed: u64) -> (World, Vec<Node>) {
    let mut t = TopologyBuilder::paper_regions();
    let hosts: Vec<u32> = (0..n).map(|_| t.public_host(0, LinkProfile::DATACENTER)).collect();
    let mut world = World::new(t.build(seed));
    let nodes: Vec<Node> = hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| {
            LatticaNode::spawn(&mut world, h, NodeConfig::with_seed(seed * 1000 + i as u64))
        })
        .collect();
    // Bootstrap everyone through node 0.
    let entry0 = PeerEntry {
        id: nodes[0].borrow().peer_id(),
        host: hosts[0],
        port: 4001,
    };
    for node in nodes.iter().skip(1) {
        node.borrow_mut().bootstrap(&mut world.net, entry0.clone());
    }
    world.run_for(3 * SECOND);
    (world, nodes)
}

fn find_event<T>(node: &Node, f: impl Fn(&NodeEvent) -> Option<T>) -> Option<T> {
    let mut n = node.borrow_mut();
    let evs = n.drain_events();
    let mut found = None;
    for e in evs {
        if found.is_none() {
            if let Some(v) = f(&e) {
                found = Some(v);
            }
        }
    }
    found
}

#[test]
fn dht_bootstrap_populates_routing_tables() {
    let (_world, nodes) = mesh(8, 31);
    for (i, n) in nodes.iter().enumerate() {
        let len = n.borrow().kad.table.len();
        assert!(len >= 3, "node {i} routing table only has {len} entries");
    }
}

#[test]
fn dht_iterative_lookup_finds_closest() {
    let (mut world, nodes) = mesh(10, 33);
    let target = *nodes[7].borrow().peer_id().as_bytes();
    {
        let n1 = &nodes[1];
        let mut n = n1.borrow_mut();
        let LatticaNode { swarm, kad, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        kad.find_node(&mut ctx, target);
    }
    let ok = run_until(&mut world, 10 * SECOND, || {
        find_event(&nodes[1], |e| match e {
            NodeEvent::Kad(KadEvent::QueryFinished { kind, closest, .. })
                if *kind == QueryKind::FindNode =>
            {
                Some(closest.first().map(|e| e.id))
            }
            _ => None,
        })
        .flatten()
        .map(|id| id == nodes[7].borrow().peer_id())
        .unwrap_or(false)
    });
    assert!(ok, "lookup did not converge on the target peer");
}

#[test]
fn publish_and_fetch_blob_via_dht_providers() {
    let (mut world, nodes) = mesh(6, 35);
    let data: Vec<u8> = (0..300_000u32).map(|i| (i % 251) as u8).collect();
    let root = nodes[2]
        .borrow_mut()
        .publish_blob(&mut world.net, "asset/test", 1, &data, 64 * 1024);
    world.run_for(2 * SECOND);

    // Node 5 resolves providers via the DHT…
    {
        let mut n = nodes[5].borrow_mut();
        let LatticaNode { swarm, kad, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        kad.get_providers(&mut ctx, root.to_key());
    }
    let provider: Option<PeerId> = {
        let mut found = None;
        run_until(&mut world, 10 * SECOND, || {
            if found.is_none() {
                found = find_event(&nodes[5], |e| match e {
                    NodeEvent::Kad(KadEvent::QueryFinished { providers, .. }) => {
                        providers.first().map(|p| p.id)
                    }
                    _ => None,
                });
            }
            found.is_some()
        });
        found
    };
    let provider = provider.expect("provider found via DHT");
    assert_eq!(provider, nodes[2].borrow().peer_id());

    // …then Bitswaps the manifest + chunks.
    nodes[5]
        .borrow_mut()
        .fetch_blob(&mut world.net, root, vec![provider]);
    run_until(&mut world, 10 * SECOND, || {
        nodes[5].borrow().blockstore.has(&root)
    });
    nodes[5]
        .borrow_mut()
        .fetch_manifest_chunks(&mut world.net, &root, vec![provider])
        .unwrap();
    let ok = run_until(&mut world, 20 * SECOND, || {
        let n = nodes[5].borrow();
        lattica::content::DagManifest::load(&n.blockstore, &root)
            .map(|m| m.is_complete(&n.blockstore))
            .unwrap_or(false)
    });
    assert!(ok, "chunks did not arrive");
    let n = nodes[5].borrow();
    let m = lattica::content::DagManifest::load(&n.blockstore, &root).unwrap();
    assert_eq!(m.assemble(&n.blockstore).unwrap(), data);
}

#[test]
fn bitswap_rejects_corrupt_blocks() {
    // A forged CID→data pair can't enter the store (verified in unit tests);
    // here we check end-to-end that only verified data lands.
    let (mut world, nodes) = mesh(3, 37);
    let data = vec![9u8; 10_000];
    let root = nodes[0]
        .borrow_mut()
        .publish_blob(&mut world.net, "x", 1, &data, 4096);
    world.run_for(SECOND);
    let provider = nodes[0].borrow().peer_id();
    nodes[1]
        .borrow_mut()
        .fetch_blob(&mut world.net, root, vec![provider]);
    run_until(&mut world, 5 * SECOND, || nodes[1].borrow().blockstore.has(&root));
    let n = nodes[1].borrow();
    let stored = n.blockstore.get(&root).unwrap();
    assert!(root.verify(&stored));
}

#[test]
fn unary_rpc_roundtrip_via_service_and_stub() {
    let (mut world, nodes) = mesh(2, 39);
    let server_peer = nodes[0].borrow().peer_id();

    // Register an echo service on node 0 (no raw event matching).
    nodes[0].borrow_mut().register_service(Service::new("echo").unary(
        "say",
        |_node, _net, _ctx, payload| {
            let mut out = b"echo:".to_vec();
            out.extend_from_slice(&payload);
            Outcome::reply(out)
        },
    ));

    let mut stub = Stub::new("echo", vec![server_peer]);
    let done = lattica::scenarios::stub_call_blocking(
        &mut world,
        &nodes[1],
        &mut stub,
        "say",
        b"hello",
        5 * SECOND,
    )
    .expect("echo response missing");
    assert_eq!(done.status, Status::Ok);
    assert_eq!(done.payload, b"echo:hello");
    assert!(done.detail.is_empty());
    assert_eq!(done.attempts, 1);
    assert_eq!(nodes[0].borrow().router_stats().served, 1);
}

#[test]
fn streaming_rpc_backpressure_delivers_in_order() {
    let (mut world, nodes) = mesh(2, 41);
    let server_peer = nodes[0].borrow().peer_id();

    // The server's stream handler is a registered service too.
    struct Collector {
        items: Rc<RefCell<Vec<(u64, Vec<u8>)>>>,
        ended: Rc<RefCell<bool>>,
    }
    impl StreamHandler for Collector {
        fn on_item(
            &mut self,
            _node: &mut LatticaNode,
            _net: &mut lattica::netsim::Net,
            _handle: lattica::rpc::StreamHandle,
            seq: u64,
            payload: lattica::util::Buf,
        ) {
            self.items.borrow_mut().push((seq, payload.to_vec()));
        }

        fn on_end(
            &mut self,
            _node: &mut LatticaNode,
            _net: &mut lattica::netsim::Net,
            _handle: lattica::rpc::StreamHandle,
        ) {
            *self.ended.borrow_mut() = true;
        }
    }
    let items = Rc::new(RefCell::new(Vec::new()));
    let ended = Rc::new(RefCell::new(false));
    nodes[0]
        .borrow_mut()
        .register_service(Service::new("tensor-flow").streaming(Collector {
            items: items.clone(),
            ended: ended.clone(),
        }));

    let handle = {
        let mut n = nodes[1].borrow_mut();
        let LatticaNode { swarm, rpc, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        rpc.open_rpc_stream(&mut ctx, &server_peer, "tensor-flow").unwrap()
    };
    // Send 50 items (more than the 16-credit initial window).
    for i in 0..50u32 {
        let mut n = nodes[1].borrow_mut();
        let LatticaNode { swarm, rpc, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        rpc.send_item(&mut ctx, handle, format!("item-{i}").into_bytes());
    }
    // Credit backpressure throttles the sender under the new API: only
    // the initial credit window is on the wire, the rest is queued.
    assert_eq!(
        nodes[1].borrow().rpc.backlog(handle),
        50 - lattica::rpc::INITIAL_CREDITS as usize,
        "sender must be throttled to the credit window"
    );
    {
        let mut n = nodes[1].borrow_mut();
        let LatticaNode { swarm, rpc, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        rpc.end_stream(&mut ctx, handle);
    }
    world.run_for(5 * SECOND);
    // Server-side handler saw all 50 items in order, then the end.
    let got = items.borrow();
    assert_eq!(got.len(), 50);
    for (i, (seq, payload)) in got.iter().enumerate() {
        assert_eq!(*seq, i as u64);
        assert_eq!(payload, &format!("item-{i}").into_bytes());
    }
    assert!(*ended.borrow(), "stream end not delivered");
    assert_eq!(nodes[0].borrow().router_stats().stream_items, 50);
}

#[test]
fn gossip_reaches_all_subscribers() {
    let (mut world, nodes) = mesh(6, 43);
    for n in &nodes {
        let mut nd = n.borrow_mut();
        let LatticaNode { swarm, gossip, .. } = &mut *nd;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        gossip.subscribe(&mut ctx, "news");
    }
    world.run_for(SECOND);
    {
        let mut nd = nodes[3].borrow_mut();
        let LatticaNode { swarm, gossip, .. } = &mut *nd;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        gossip.publish(&mut ctx, "news", b"model v7 available".to_vec());
    }
    world.run_for(3 * SECOND);
    let mut received = 0;
    for (i, n) in nodes.iter().enumerate() {
        if i == 3 {
            continue;
        }
        let got = find_event(n, |e| match e {
            NodeEvent::Gossip(lattica::protocols::gossip::GossipEvent::Received {
                data, ..
            }) => Some(data == b"model v7 available"),
            _ => None,
        })
        .unwrap_or(false);
        if got {
            received += 1;
        }
    }
    assert_eq!(received, 5, "gossip must reach all subscribers");
}

#[test]
fn crdt_anti_entropy_converges() {
    let (mut world, nodes) = mesh(3, 45);
    // Divergent updates.
    nodes[0].borrow_mut().crdt.gcounter("steps").increment(1, 5);
    nodes[1].borrow_mut().crdt.gcounter("steps").increment(2, 7);
    nodes[2].borrow_mut().crdt.orset("members").add(3, b"n2");
    // Ring sync: 0→1, 1→2, 2→0, then once more.
    for _ in 0..2 {
        for (a, b) in [(0, 1), (1, 2), (2, 0)] {
            let peer = nodes[b].borrow().peer_id();
            nodes[a]
                .borrow_mut()
                .crdt_sync_with(&mut world.net, &peer)
                .unwrap();
            world.run_for(SECOND);
        }
    }
    let d0 = nodes[0].borrow().crdt.digest();
    let d1 = nodes[1].borrow().crdt.digest();
    let d2 = nodes[2].borrow().crdt.digest();
    assert_eq!(d0, d1);
    assert_eq!(d1, d2);
    assert_eq!(nodes[0].borrow_mut().crdt.gcounter("steps").value(), 12);
}

#[test]
fn rendezvous_register_and_discover() {
    let mut t = TopologyBuilder::paper_regions();
    let hs = t.public_host(0, LinkProfile::DATACENTER);
    let ha = t.public_host(1, LinkProfile::FIBER);
    let hb = t.public_host(2, LinkProfile::FIBER);
    let mut world = World::new(t.build(47));
    let server = LatticaNode::spawn(&mut world, hs, {
        let mut c = NodeConfig::with_seed(100);
        c.rendezvous_server = true;
        c
    });
    let a = LatticaNode::spawn(&mut world, ha, NodeConfig::with_seed(101));
    let b = LatticaNode::spawn(&mut world, hb, NodeConfig::with_seed(102));
    let server_ma = server.borrow().listen_addr();
    let server_peer = server.borrow().peer_id();
    a.borrow_mut().dial(&mut world.net, &server_ma).unwrap();
    b.borrow_mut().dial(&mut world.net, &server_ma).unwrap();
    world.run_for(2 * SECOND);
    {
        let mut n = a.borrow_mut();
        let LatticaNode { swarm, rendezvous, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        rendezvous.register(&mut ctx, &server_peer, "shard-cluster").unwrap();
    }
    world.run_for(SECOND);
    {
        let mut n = b.borrow_mut();
        let LatticaNode { swarm, rendezvous, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        rendezvous.discover(&mut ctx, &server_peer, "shard-cluster").unwrap();
    }
    let a_peer = a.borrow().peer_id();
    let ok = run_until(&mut world, 5 * SECOND, || {
        find_event(&b, |e| match e {
            NodeEvent::Rendezvous(
                lattica::protocols::rendezvous::RendezvousEvent::Discovered { peers, .. },
            ) => Some(peers.iter().any(|p| p.id == a_peer)),
            _ => None,
        })
        .unwrap_or(false)
    });
    assert!(ok, "rendezvous discovery failed");
}

#[test]
fn natted_fetch_through_relay_after_traversal() {
    // Edge node behind symmetric NAT fetches content from another NATed
    // node via the relay (the fallback path of Fig. 1(1)).
    let mut t = TopologyBuilder::paper_regions();
    let hr = t.public_host(0, LinkProfile::DATACENTER);
    let na = t.nat(1, NatType::Symmetric, LinkProfile::FIBER);
    let ha = t.natted_host(na, LinkProfile::UNLIMITED);
    let nb = t.nat(2, NatType::Symmetric, LinkProfile::FIBER);
    let hb = t.natted_host(nb, LinkProfile::UNLIMITED);
    let mut world = World::new(t.build(49));
    let relay = LatticaNode::spawn(&mut world, hr, NodeConfig::relay(200));
    let a = LatticaNode::spawn(&mut world, ha, NodeConfig::with_seed(201));
    let b = LatticaNode::spawn(&mut world, hb, NodeConfig::with_seed(202));
    let relay_ma = relay.borrow().listen_addr();
    let relay_peer = relay.borrow().peer_id();
    a.borrow_mut().dial(&mut world.net, &relay_ma).unwrap();
    b.borrow_mut().dial(&mut world.net, &relay_ma).unwrap();
    world.run_for(2 * SECOND);
    // B reserves; A publishes content; A dials B via circuit; B fetches.
    {
        let mut n = b.borrow_mut();
        let LatticaNode { swarm, .. } = &mut *n;
        swarm.relay_reserve(&mut world.net, &relay_peer).unwrap();
    }
    world.run_for(SECOND);
    let data = vec![5u8; 50_000];
    let root = a
        .borrow_mut()
        .publish_blob(&mut world.net, "edge-data", 1, &data, 16 * 1024);
    let b_peer = b.borrow().peer_id();
    let circuit = lattica::multiaddr::Multiaddr::circuit(relay_ma.clone(), b_peer);
    a.borrow_mut().dial(&mut world.net, &circuit).unwrap();
    let connected = run_until(&mut world, 10 * SECOND, || {
        a.borrow().swarm.is_connected(&b_peer)
    });
    assert!(connected, "relayed connection failed");
    // B fetches from A across the circuit.
    let a_peer = a.borrow().peer_id();
    b.borrow_mut().fetch_blob(&mut world.net, root, vec![a_peer]);
    let got_manifest = run_until(&mut world, 15 * SECOND, || {
        b.borrow().blockstore.has(&root)
    });
    assert!(got_manifest, "manifest fetch over relay failed");
    b.borrow_mut()
        .fetch_manifest_chunks(&mut world.net, &root, vec![a_peer])
        .unwrap();
    let ok = run_until(&mut world, 30 * SECOND, || {
        let n = b.borrow();
        lattica::content::DagManifest::load(&n.blockstore, &root)
            .map(|m| m.is_complete(&n.blockstore))
            .unwrap_or(false)
    });
    assert!(ok, "chunk fetch over relay failed");
    let _ = Cid::of(b"unused");
    let _ = Proto::QuicLike;
}
