//! Inference-plane acceptance tests (DESIGN.md §Inference plane).
//!
//! Always-on: wire/ad codec roundtrips and hostile-input rejection.
//! Release-gated: the full geo-distributed serving scenarios — routed
//! beats the placement-blind static chain, and a mid-chain replica kill
//! mid-stream completes every request via splice-repair + replay with
//! zero duplicate KV appends.

use lattica::identity::Keypair;
use lattica::route::{Hop, LayerAd, OpenFrame, RouteFrame};
use lattica::scenarios::{route_inference, RouteScenarioConfig};
use lattica::wire::Message;

fn peer(seed: u64) -> lattica::identity::PeerId {
    Keypair::from_seed(seed).peer_id()
}

#[test]
fn route_frame_roundtrips() {
    let chain: Vec<Hop> = (0..3)
        .map(|i| Hop {
            peer: peer(i),
            host: 10 + i as u32,
            port: 4001,
            layers: (i as u32 * 4, (i as u32 + 1) * 4),
        })
        .collect();
    let open = RouteFrame::Open(OpenFrame {
        request: 7,
        generation: 2,
        model: "sim-tiny".into(),
        hop_index: 1,
        n_prompt: 5,
        client: Hop { peer: peer(99), host: 1, port: 4001, layers: (0, 0) },
        chain,
    });
    for f in [
        open,
        RouteFrame::Token { request: 7, pos: 4, token: 19 },
        RouteFrame::Act { request: 7, pos: 4, hidden: vec![0.5, -1.25, 3.0] },
        RouteFrame::Emit { request: 7, pos: 9, token: 3 },
        RouteFrame::Fault { request: 7, hop_index: 1, detail: "died".into() },
    ] {
        let bytes = f.encode();
        let back = RouteFrame::decode(&bytes).expect("roundtrip");
        assert_eq!(back.encode(), bytes);
    }
}

#[test]
fn hostile_route_frames_rejected() {
    // Truncations of every valid frame must error, never panic.
    let f = RouteFrame::Act { request: 1, pos: 0, hidden: vec![1.0; 8] };
    let bytes = f.encode();
    for cut in 0..bytes.len() {
        let _ = RouteFrame::decode(&bytes[..cut]);
    }
    // Semantically invalid ads are rejected on decode.
    let ad = LayerAd {
        peer: peer(1),
        host: 9,
        port: 4001,
        model: "m".into(),
        layers: (8, 4), // inverted range
        region: 0,
        capacity: 10,
        load: 5,
        rtts: Vec::new(),
    };
    assert!(LayerAd::decode(&ad.encode()).is_err());
}

#[test]
fn quick_routed_scenario_completes() {
    let out = route_inference(&RouteScenarioConfig::quick(true, false));
    assert_eq!(out.failed, 0, "quick routed run had failures");
    assert_eq!(out.completed, out.requests);
    assert!(out.reference_match, "outputs diverged from the oracle");
    assert_eq!(out.duplicate_appends, 0);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-mode scenario; run via CI or --include-ignored")]
fn routed_beats_static() {
    let mut routed = route_inference(&RouteScenarioConfig::ci(true, false));
    let mut naive = route_inference(&RouteScenarioConfig::ci(false, false));
    for (name, o) in [("routed", &routed), ("static", &naive)] {
        assert_eq!(o.failed, 0, "{name}: failures");
        assert!(o.reference_match, "{name}: outputs diverged from the oracle");
    }
    assert!(routed.dht_holders >= 1, "layer bucket has no DHT providers");
    assert!(
        routed.ttft.percentile(99.0) < naive.ttft.percentile(99.0),
        "routed p99 TTFT {} must beat static {}",
        routed.ttft.percentile(99.0),
        naive.ttft.percentile(99.0)
    );
    assert!(
        routed.tokens_per_sec > naive.tokens_per_sec,
        "routed {} tok/s must beat static {} tok/s",
        routed.tokens_per_sec,
        naive.tokens_per_sec
    );
}

#[test]
#[cfg_attr(debug_assertions, ignore = "release-mode scenario; run via CI or --include-ignored")]
fn mid_chain_kill_completes_with_replay() {
    let out = route_inference(&RouteScenarioConfig::ci(true, true));
    assert_eq!(out.failed, 0, "kill must be client-invisible");
    assert_eq!(out.completed, out.requests);
    assert!(out.repairs >= 1, "no chain repair happened");
    assert!(out.reference_match, "replayed outputs diverged from the oracle");
    assert_eq!(
        out.duplicate_appends, 0,
        "replay must recompute via generation reset, never double-append"
    );
    assert!(out.shard_stats.sessions_reset >= 1, "no session was replay-reset");
}
