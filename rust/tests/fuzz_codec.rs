//! Fuzz-style corpus tests for the wire codecs: random truncations,
//! flipped bytes, garbage, and hostile length prefixes must produce
//! errors — never panics, and never allocation blow-ups driven by
//! attacker-controlled length claims.
//!
//! A peak-tracking global allocator bounds transient memory during decode
//! of hostile buffers (the "never over-allocate" half of the contract).

use lattica::content::{Cid, DagManifest, DeltaManifest};
use lattica::crdt::CrdtStore;
use lattica::identity::Keypair;
use lattica::node::relay::RelayAd;
use lattica::protocols::bitswap::BitswapMsg;
use lattica::protocols::dcutr::DcutrMsg;
use lattica::protocols::gossip::{GossipMsg, GossipSummary};
use lattica::protocols::kad::{KadMsg, PeerEntry};
use lattica::route::{Hop, LayerAd, OpenFrame, RouteFrame};
use lattica::rpc::RpcMsg;
use lattica::runtime::Tensor;
use lattica::shard::ShardRequest;
use lattica::util::buf::Buf;
use lattica::util::varint;
use lattica::util::Rng;
use lattica::wire::{BloomDigest, Message, PbReader, PbWriter, RangeSet};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicI64, Ordering};

struct PeakAlloc;

static CUR: AtomicI64 = AtomicI64::new(0);
static PEAK: AtomicI64 = AtomicI64::new(0);

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let cur = CUR.fetch_add(layout.size() as i64, Ordering::Relaxed) + layout.size() as i64;
        PEAK.fetch_max(cur, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        CUR.fetch_sub(layout.size() as i64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let delta = new_size as i64 - layout.size() as i64;
        let cur = CUR.fetch_add(delta, Ordering::Relaxed) + delta;
        PEAK.fetch_max(cur, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: PeakAlloc = PeakAlloc;

fn entry(seed: u64) -> PeerEntry {
    PeerEntry {
        id: Keypair::from_seed(seed).peer_id(),
        host: seed as u32,
        port: 4001,
    }
}

/// Valid encodings to mutate: empty, small, and fully-populated messages.
fn kad_corpus() -> Vec<Vec<u8>> {
    let full = KadMsg {
        kind: 6,
        key: vec![7u8; 32],
        closer: (1..=5u64).map(entry).collect(),
        providers: vec![entry(9), entry(10)],
        value: vec![0xAB; 200],
        found: true,
        provider: Some(entry(11)),
    };
    let small = KadMsg {
        kind: 1,
        key: vec![1u8; 32],
        ..Default::default()
    };
    let mut store = CrdtStore::new();
    store.gcounter("steps").increment(1, 5);
    store.orset("members").add(2, b"alice");
    store.lww("leader").set(b"n7".to_vec(), 9, 1);
    let manifest = DagManifest {
        name: "model/ckpt-7".into(),
        version: 7,
        total_size: 96_000,
        chunks: (0..6u8).map(|i| Cid::of(&[i])).collect(),
    };
    let delta = DeltaManifest {
        name: "model/ckpt-8".into(),
        version: 8,
        base_version: 7,
        base_root: Cid::of(b"base"),
        root: Cid::of(b"next"),
        total_size: 96_000,
        added: (0..3u8).map(|i| Cid::of(&[0x40 | i])).collect(),
        added_bytes: 48_000,
    };
    let want = BitswapMsg {
        kind: 6, // WANT_HAVE
        cids: (0..4u8).map(|i| Cid::of(&[0x80 | i])).collect(),
        block: Buf::new(),
        ..Default::default()
    };
    let block = BitswapMsg {
        kind: 2, // BLOCK
        cids: vec![Cid::of(b"payload")],
        block: vec![0xAB; 400].into(),
        ..Default::default()
    };
    // Compact bitswap addressing: (root, range-coded index set).
    let compact_want = BitswapMsg {
        kind: 6, // WANT_HAVE
        root: Some(Cid::of(b"manifest-root")),
        indexes: (0u64..512).chain(900..910).collect::<RangeSet>().encode(),
        ..Default::default()
    };
    // Gossip frames: a legacy publish plus the lazy-push IHAVE/IWANT pair
    // with range-coded per-origin summaries and a bloom digest.
    let publish = GossipMsg {
        kind: 1, // PUBLISH
        topic: "checkpoints".into(),
        origin: Keypair::from_seed(3).peer_id().as_bytes().to_vec(),
        seq: 7,
        data: vec![0xCD; 120],
        ..Default::default()
    };
    let summary = GossipSummary {
        origin: Keypair::from_seed(4).peer_id().as_bytes().to_vec(),
        seqs: (1u64..40).collect::<RangeSet>().encode(),
    };
    let mut bloom = BloomDigest::new();
    for i in 0..40u64 {
        bloom.insert(&i.to_be_bytes());
    }
    let ihave = GossipMsg {
        kind: 4, // IHAVE
        summaries: vec![summary.clone()],
        digest: bloom.as_bytes().to_vec(),
        ..Default::default()
    };
    let iwant = GossipMsg {
        kind: 5, // IWANT
        summaries: vec![summary],
        ..Default::default()
    };
    // RPC request with the deadline/detail fields populated…
    let rpc_req = RpcMsg {
        kind: 1, // REQUEST
        service: "shard".into(),
        method: "forward".into(),
        payload: vec![0x5A; 300].into(),
        deadline_ns: 123_456_789_000,
        ..Default::default()
    };
    let rpc_resp = RpcMsg {
        kind: 2, // RESPONSE
        status: 3,
        error_detail: "replica down".into(),
        ..Default::default()
    };
    // Server pushback: an `Overloaded` (status 4) response carrying the
    // retry-after hint (field 9) — the overload-control frame class.
    let rpc_pushback = RpcMsg {
        kind: 2, // RESPONSE
        status: 4,
        error_detail: "service \"shard\" overloaded".into(),
        retry_after_ns: 250_000_000,
        ..Default::default()
    };
    // …and a legacy pre-`deadline_ns` encoding (fields 1–6 only), exactly
    // as an old peer would put it on the wire.
    let mut legacy = PbWriter::new();
    legacy.uint(1, 1);
    legacy.string(2, "shard");
    legacy.string(3, "forward");
    legacy.bytes(4, &[7u8; 64]);
    legacy.uint(6, 2);
    // Handcrafted pushback wire frame: status 4 plus a bare field 9, the
    // minimal overload signal a foreign implementation might emit.
    let mut pushback_wire = PbWriter::new();
    pushback_wire.uint(1, 2);
    pushback_wire.uint(5, 4);
    pushback_wire.uint(6, 99);
    pushback_wire.uint(9, 1_000_000);
    // NAT traversal control frames: a DCUtR CONNECT/DENY pair and a relay
    // gossip ad (all carry ports, the truncation-prone field class).
    let dcutr_connect = DcutrMsg {
        kind: 1,
        host: 42,
        port: 65_000,
        ..Default::default()
    };
    let dcutr_deny = DcutrMsg {
        kind: 3,
        error: "no observed external address".into(),
        ..Default::default()
    };
    let relay_ad = RelayAd {
        peer: Keypair::from_seed(5).peer_id(),
        host: 9,
        port: 4001,
        load: 63,
    };
    // Inference-plane frames: the shard request (tokens and hidden-tensor
    // forms), a tensor as the response payload, the route-stream frame
    // family, and a layer ad with a piggybacked RTT sample.
    let shard_tokens = ShardRequest {
        request_id: 9,
        tokens: (0..32).collect(),
        hidden: None,
    };
    let shard_resp = Tensor::from_f32(&[1, 4], &[1.0, -2.0, 3.5, 0.25]);
    let shard_hidden = ShardRequest {
        request_id: 10,
        tokens: vec![],
        hidden: Some(shard_resp.clone()),
    };
    let hop = |i: u64| Hop {
        peer: Keypair::from_seed(20 + i).peer_id(),
        host: i as u32,
        port: 4001,
        layers: (i as u32 * 4, i as u32 * 4 + 4),
    };
    let route_open = RouteFrame::Open(OpenFrame {
        request: 3,
        generation: 1,
        model: "sim-tiny".into(),
        hop_index: 0,
        n_prompt: 4,
        client: Hop {
            peer: Keypair::from_seed(30).peer_id(),
            host: 9,
            port: 4001,
            layers: (0, 0),
        },
        chain: vec![hop(0), hop(1), hop(2)],
    });
    let route_act = RouteFrame::Act {
        request: 3,
        pos: 2,
        hidden: vec![0.5; 16],
    };
    let route_fault = RouteFrame::Fault {
        request: 3,
        hop_index: 1,
        detail: "downstream stream ended".into(),
    };
    let layer_ad = LayerAd {
        peer: Keypair::from_seed(31).peer_id(),
        host: 7,
        port: 4001,
        model: "sim-tiny".into(),
        layers: (4, 8),
        region: 2,
        capacity: 1 << 16,
        load: 35,
        rtts: vec![(Keypair::from_seed(32).peer_id(), 12_000_000)],
    };
    vec![
        full.encode(),
        small.encode(),
        KadMsg::default().encode(),
        store.encode(),
        manifest.encode(),
        DagManifest::default().encode(),
        delta.encode(),
        want.encode(),
        block.encode(),
        BitswapMsg::default().encode(),
        rpc_req.encode(),
        rpc_resp.encode(),
        rpc_pushback.encode(),
        legacy.finish(),
        pushback_wire.finish(),
        compact_want.encode(),
        publish.encode(),
        ihave.encode(),
        iwant.encode(),
        GossipMsg::default().encode(),
        dcutr_connect.encode(),
        dcutr_deny.encode(),
        relay_ad.encode(),
        shard_tokens.encode(),
        shard_hidden.encode(),
        shard_resp.encode(),
        route_open.encode(),
        route_act.encode(),
        route_fault.encode(),
        layer_ad.encode(),
    ]
}

fn decode_everything(buf: &[u8]) {
    // Outcomes are irrelevant; the contract is "Err, not panic".
    let _ = KadMsg::decode(buf);
    let _ = KadMsg::decode_buf(&Buf::from_vec(buf.to_vec()));
    let _ = CrdtStore::decode(buf);
    let _ = DagManifest::decode(buf);
    let _ = DeltaManifest::decode(buf);
    let _ = BitswapMsg::decode(buf);
    let _ = BitswapMsg::decode_buf(&Buf::from_vec(buf.to_vec()));
    let _ = RpcMsg::decode(buf);
    let _ = RpcMsg::decode_buf(&Buf::from_vec(buf.to_vec()));
    let _ = GossipMsg::decode(buf);
    let _ = GossipMsg::decode_buf(&Buf::from_vec(buf.to_vec()));
    let _ = RangeSet::decode(buf);
    let _ = BloomDigest::from_bytes(buf);
    let _ = lattica::model::ModelAnnouncement::decode(buf);
    let _ = DcutrMsg::decode(buf);
    let _ = RelayAd::decode(buf);
    let _ = ShardRequest::decode(buf);
    let _ = Tensor::decode(buf);
    let _ = RouteFrame::decode(buf);
    let _ = LayerAd::decode(buf);
    // The raw field reader must also survive anything.
    let mut r = PbReader::new(buf);
    while let Ok(Some(f)) = r.next_field() {
        let _ = f.as_bytes();
        let _ = f.as_string();
        let _ = f.as_double();
        let _ = f.packed_uints();
    }
}

#[test]
fn truncations_never_panic() {
    for base in kad_corpus() {
        for cut in 0..base.len() {
            decode_everything(&base[..cut]);
        }
        // A strict prefix of a length-delimited field must be an error for
        // the full-message decoder (not silently accepted as complete).
        if base.len() > 2 {
            assert!(
                KadMsg::decode(&base[..base.len() - 1]).is_err()
                    || CrdtStore::decode(&base[..base.len() - 1]).is_err()
                    || base.len() < 4,
                "dropping the last byte of a message with trailing payload \
                 should break a decoder"
            );
        }
    }
}

#[test]
fn flipped_bytes_never_panic() {
    let corpus = kad_corpus();
    let mut rng = Rng::new(0xF1_1B);
    for _ in 0..3000 {
        let base = &corpus[rng.gen_index(corpus.len())];
        if base.is_empty() {
            continue;
        }
        let mut m = base.clone();
        for _ in 0..1 + rng.gen_index(8) {
            let i = rng.gen_index(m.len());
            m[i] ^= 1 << rng.gen_index(8);
        }
        decode_everything(&m);
    }
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = Rng::new(0x6A_4B);
    for _ in 0..2000 {
        let len = rng.gen_index(300);
        let garbage = rng.gen_bytes(len);
        decode_everything(&garbage);
    }
}

#[test]
fn oversized_length_prefix_errors_without_allocating() {
    // Field 2 (bytes), claimed length 2^40 with no data behind it: the
    // decoder must reject it before allocating anything near the claim.
    let mut hostile = Vec::new();
    varint::put_uvarint(&mut hostile, (2 << 3) | 2); // field 2, wire type Len
    varint::put_uvarint(&mut hostile, 1u64 << 40);
    hostile.extend_from_slice(&[0u8; 16]);

    // Same but the claim barely exceeds the remaining bytes.
    let mut off_by_one = Vec::new();
    varint::put_uvarint(&mut off_by_one, (2 << 3) | 2);
    varint::put_uvarint(&mut off_by_one, 17);
    off_by_one.extend_from_slice(&[0u8; 16]);

    for hostile in [&hostile, &off_by_one] {
        PEAK.store(CUR.load(Ordering::Relaxed), Ordering::Relaxed);
        let before = PEAK.load(Ordering::Relaxed);
        assert!(KadMsg::decode(hostile).is_err());
        assert!(CrdtStore::decode(hostile).is_err());
        assert!(DagManifest::decode(hostile).is_err());
        assert!(DeltaManifest::decode(hostile).is_err());
        assert!(BitswapMsg::decode(hostile).is_err());
        assert!(RpcMsg::decode(hostile).is_err());
        assert!(GossipMsg::decode(hostile).is_err());
        assert!(BloomDigest::from_bytes(hostile).is_err());
        assert!(ShardRequest::decode(hostile).is_err());
        assert!(RouteFrame::decode(hostile).is_err());
        assert!(LayerAd::decode(hostile).is_err());
        let mut r = PbReader::new(hostile);
        loop {
            match r.next_field() {
                Ok(Some(_)) => {}
                Ok(None) => break,
                Err(_) => break,
            }
        }
        let grew = PEAK.load(Ordering::Relaxed) - before;
        // Tolerate incidental small allocations (error strings etc.), but
        // nothing remotely sized by the hostile length claim.
        assert!(
            grew < (1 << 20),
            "decode of a hostile length prefix allocated {grew} bytes"
        );
    }

    // Shard requests are varint-framed (not pb): a claimed 2^40-token
    // batch in a 7-byte frame must error before any allocation sized by
    // the claim.
    let mut shard_hostile = Vec::new();
    varint::put_uvarint(&mut shard_hostile, 1); // request_id
    varint::put_uvarint(&mut shard_hostile, 1u64 << 40); // token count
    PEAK.store(CUR.load(Ordering::Relaxed), Ordering::Relaxed);
    let before = PEAK.load(Ordering::Relaxed);
    assert!(ShardRequest::decode(&shard_hostile).is_err());
    let grew = PEAK.load(Ordering::Relaxed) - before;
    assert!(
        grew < (1 << 20),
        "hostile shard token count allocated {grew} bytes"
    );
}

#[test]
fn corpus_roundtrips_stay_valid() {
    // Sanity: the corpus really is decodable, so the fuzz cases above are
    // exercising real decode paths, not failing at byte 0.
    let full = KadMsg {
        kind: 6,
        key: vec![7u8; 32],
        closer: vec![entry(1)],
        providers: vec![entry(2)],
        value: b"v".to_vec(),
        found: true,
        provider: Some(entry(3)),
    };
    assert_eq!(KadMsg::decode(&full.encode()).unwrap(), full);
    let buf = Buf::from_vec(full.encode());
    assert_eq!(KadMsg::decode_buf(&buf).unwrap(), full);
    // The new corpus members roundtrip too (so their fuzz arms exercise
    // real decode paths).
    for base in kad_corpus().into_iter().skip(4) {
        if base.is_empty() {
            continue;
        }
        let ok = DagManifest::decode(&base).is_ok()
            || DeltaManifest::decode(&base).is_ok()
            || BitswapMsg::decode(&base).is_ok()
            || RpcMsg::decode(&base).is_ok()
            || GossipMsg::decode(&base).is_ok()
            || DcutrMsg::decode(&base).is_ok()
            || RelayAd::decode(&base).is_ok()
            || ShardRequest::decode(&base).is_ok()
            || Tensor::decode(&base).is_ok()
            || RouteFrame::decode(&base).is_ok()
            || LayerAd::decode(&base).is_ok();
        assert!(ok, "corpus entry decodes under none of its codecs");
    }
    // Compact/lazy-push frames roundtrip exactly, including the nested
    // range-coded payloads.
    let compact = BitswapMsg {
        kind: 6,
        root: Some(Cid::of(b"manifest-root")),
        indexes: (0u64..512).collect::<RangeSet>().encode(),
        ..Default::default()
    };
    assert_eq!(BitswapMsg::decode(&compact.encode()).unwrap(), compact);
    let ihave = GossipMsg {
        kind: 4,
        summaries: vec![GossipSummary {
            origin: Keypair::from_seed(4).peer_id().as_bytes().to_vec(),
            seqs: (1u64..40).collect::<RangeSet>().encode(),
        }],
        digest: BloomDigest::new().as_bytes().to_vec(),
        ..Default::default()
    };
    assert_eq!(GossipMsg::decode(&ihave.encode()).unwrap(), ihave);
    // Nested hostile bytes inside a *valid* outer frame: a PeerEntry field
    // with a wrong-size id must error, not panic.
    let mut w = PbWriter::new();
    w.uint(1, 6);
    w.bytes_always(3, &{
        let mut inner = PbWriter::new();
        inner.bytes_always(1, &[0u8; 31]); // bad peer id length
        inner.finish()
    });
    assert!(KadMsg::decode(&w.finish()).is_err());
}

#[test]
fn oversized_ports_rejected_at_decode() {
    // Ports ride the wire as varints; a value above u16::MAX would
    // silently truncate at the punch/dial site (`as u16`) if a decoder
    // accepted it. Both port-carrying codecs must reject instead.
    let mut dcutr = PbWriter::new();
    dcutr.uint(1, 1); // CONNECT
    dcutr.uint(2, 42); // host
    dcutr.uint(3, 70_000); // port > u16::MAX
    assert!(DcutrMsg::decode(&dcutr.finish()).is_err());

    let mut ad = PbWriter::new();
    ad.bytes(1, Keypair::from_seed(6).peer_id().as_bytes());
    ad.uint(2, 9);
    ad.uint(3, 1 << 20); // port way out of range
    assert!(RelayAd::decode(&ad.finish()).is_err());

    // The boundary value itself is fine.
    let edge = DcutrMsg {
        kind: 2,
        host: 1,
        port: u16::MAX as u32,
        ..Default::default()
    };
    assert_eq!(DcutrMsg::decode(&edge.encode()).unwrap(), edge);
}
