//! Deterministic loss-recovery traces for the congestion-control
//! subsystem: fast retransmit on dup-ack ranges (no RTO), NewReno AIMD
//! window shape, pacing of `poll_output`, priority scheduling, and the
//! CUBIC-vs-NewReno throughput comparison on the high-BDP scenario.
//!
//! Run in CI as `cargo test --release --test cc_recovery`.

use lattica::identity::Keypair;
use lattica::netsim::{Time, MILLI, SECOND};
use lattica::rpc::{Status, Stub};
use lattica::scenarios::{echo_service, table1_world_cc, NetScenario};
use lattica::transport::cc::{CcAlgorithm, INITIAL_CWND, MSS};
use lattica::transport::connection::{ConnEvent, Connection, ConnectionConfig, Role};
use lattica::transport::packet::Packet;
use lattica::transport::TrafficClass;
use lattica::util::buf::Buf;
use lattica::util::Rng;

/// Two connections driven directly with a hand-held clock (no simulator):
/// every packet drop, delivery time and ACK is explicit.
struct Pair {
    a: Connection,
    b: Connection,
    now: Time,
}

impl Pair {
    fn new(cc: CcAlgorithm, pacing: bool) -> Pair {
        let mut rng = Rng::new(42);
        let cfg = ConnectionConfig {
            cc,
            pacing,
            ..ConnectionConfig::default()
        };
        let a = Connection::new(Role::Client, cfg.clone(), Keypair::from_seed(1), 0, &mut rng);
        let b = Connection::new(Role::Server, cfg, Keypair::from_seed(2), 0, &mut rng);
        Pair { a, b, now: 0 }
    }

    /// Lockstep exchange advancing `step` per round until quiescent.
    fn pump(&mut self, step: Time) {
        let mut rounds = 0;
        loop {
            self.now += step;
            let out_a = self.a.poll_output(self.now);
            let out_b = self.b.poll_output(self.now);
            if out_a.is_empty() && out_b.is_empty() {
                break;
            }
            for p in out_a {
                self.b.handle_packet(self.now, Packet::decode(&p).unwrap()).unwrap();
            }
            for p in out_b {
                self.a.handle_packet(self.now, Packet::decode(&p).unwrap()).unwrap();
            }
            rounds += 1;
            assert!(rounds < 5000, "pump did not converge");
        }
    }

    fn msgs(conn: &mut Connection) -> Vec<Buf> {
        let mut out = Vec::new();
        while let Some(ev) = conn.poll_event() {
            if let ConnEvent::Msg { msg, .. } = ev {
                out.push(msg);
            }
        }
        out
    }
}

/// (a) Fast retransmit fires on 3 dup-ack-ranges without waiting for RTO.
#[test]
fn fast_retransmit_on_three_dup_ack_ranges() {
    // Establish over a ~8 ms round trip so the reorder window (srtt/4) is
    // well above the 1 ms send spacing used below.
    let mut p = Pair::new(CcAlgorithm::NewReno, false);
    p.pump(4 * MILLI);
    assert!(p.a.is_established() && p.b.is_established());
    Pair::msgs(&mut p.b);

    let sid = p.a.open_stream("/cc/fast/1");
    // Five spaced sends; the first flight is dropped on the floor.
    let mut flights: Vec<Vec<Vec<u8>>> = Vec::new();
    for i in 0..5u8 {
        p.now += MILLI;
        p.a.send_msg(sid, &[i; 64]).unwrap();
        flights.push(p.a.poll_output(p.now));
    }
    assert!(!flights[0].is_empty(), "first flight must exist to be droppable");
    drop(flights.remove(0));
    // Deliver the surviving flights; ACK each individually (the delayed-ACK
    // deadline is 1 ms), producing dup-ack ranges with a growing gap.
    for flight in flights {
        for pkt in flight {
            p.b.handle_packet(p.now, Packet::decode(&pkt).unwrap()).unwrap();
        }
        p.now += MILLI;
        for ack in p.b.poll_output(p.now) {
            p.a.handle_packet(p.now, Packet::decode(&ack).unwrap()).unwrap();
        }
    }
    assert_eq!(p.a.fast_retransmits, 1, "3 dup-ack ranges must trigger fast retransmit");
    assert_eq!(p.a.rto_events, 0, "recovery must not wait for (or count as) an RTO");
    assert!(p.a.packets_retransmitted >= 1);
    // The retransmission completes delivery.
    p.pump(MILLI);
    let got = Pair::msgs(&mut p.b);
    assert_eq!(got.len(), 5, "all five messages must arrive, got {}", got.len());
}

/// (b) cwnd halves on loss and grows again — the NewReno AIMD shape.
#[test]
fn newreno_cwnd_halves_on_loss_and_regrows() {
    let mut p = Pair::new(CcAlgorithm::NewReno, false);
    p.pump(MILLI);
    let cwnd0 = p.a.stats().cwnd;
    assert_eq!(cwnd0, INITIAL_CWND);

    // Phase 1: a window-limited transfer grows the window (slow start).
    let sid = p.a.open_stream("/cc/aimd/1");
    p.a.send_msg(sid, &vec![1u8; 200_000]).unwrap();
    p.pump(MILLI);
    let grown = p.a.stats().cwnd;
    assert!(grown > cwnd0, "slow start must grow cwnd: {grown} vs {cwnd0}");
    Pair::msgs(&mut p.b);

    // Phase 2: drop one spaced flight → fast retransmit → halving.
    let mut flights = Vec::new();
    for i in 0..5u8 {
        p.now += MILLI;
        p.a.send_msg(sid, &[i; 64]).unwrap();
        flights.push(p.a.poll_output(p.now));
    }
    flights.remove(0); // lost
    for flight in flights {
        for pkt in flight {
            p.b.handle_packet(p.now, Packet::decode(&pkt).unwrap()).unwrap();
        }
        p.now += MILLI;
        for ack in p.b.poll_output(p.now) {
            p.a.handle_packet(p.now, Packet::decode(&ack).unwrap()).unwrap();
        }
    }
    assert!(p.a.fast_retransmits >= 1, "loss must be recovered without RTO");
    let halved = p.a.stats().cwnd;
    assert!(
        halved <= grown * 6 / 10 && halved >= grown * 4 / 10,
        "cwnd must roughly halve on loss: {halved} vs {grown}"
    );
    p.pump(MILLI);

    // Phase 3: congestion avoidance grows the window again, slowly
    // (several windows of data earn several MSS of growth).
    p.a.send_msg(sid, &vec![2u8; 1_000_000]).unwrap();
    p.pump(MILLI);
    let regrown = p.a.stats().cwnd;
    assert!(
        regrown >= halved + 2 * MSS,
        "AIMD must grow cwnd again: {regrown} vs {halved}"
    );
    assert!(
        regrown < grown * 2,
        "post-loss growth must be additive, not slow-start: {regrown} vs {grown}"
    );
}

/// Pacing: one `poll_output` call emits a bounded burst, exposes a refill
/// deadline, and the transfer still completes as time advances.
#[test]
fn pacer_bounds_burst_and_schedules_refill() {
    let mut p = Pair::new(CcAlgorithm::Cubic, true);
    p.pump(MILLI);
    let sid = p.a.open_stream("/cc/paced/1");
    p.a.send_msg(sid, &vec![7u8; 200_000]).unwrap();
    p.now += MILLI;
    let first: usize = p.a.poll_output(p.now).iter().map(|x| x.len()).sum();
    assert!(first > 0, "pacer must admit an initial burst");
    assert!(
        first < 40_000,
        "one instant must not flush the whole message: {first} bytes"
    );
    // The connection reports when the bucket refills.
    let deadline = p.a.next_timeout(p.now).expect("pacer deadline");
    assert!(
        deadline > p.now && deadline <= p.now + 20 * MILLI,
        "refill deadline must be near: {} vs now {}",
        deadline,
        p.now
    );
    p.pump(MILLI);
    let got = Pair::msgs(&mut p.b);
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].len(), 200_000);
}

/// Priority scheduler: a control-class stream preempts a bulk backlog.
#[test]
fn control_stream_preempts_bulk_backlog() {
    let mut p = Pair::new(CcAlgorithm::Cubic, false);
    p.pump(MILLI);
    let bulk = p.a.open_stream_class("/cc/bulk/1", TrafficClass::Bulk);
    let ctl = p.a.open_stream_class("/cc/ctl/1", TrafficClass::Control);
    // Deep bulk backlog first, then a small control message.
    p.a.send_msg(bulk, &vec![9u8; 500_000]).unwrap();
    p.a.send_msg(ctl, b"urgent").unwrap();
    p.now += MILLI;
    let out = p.a.poll_output(p.now);
    assert!(!out.is_empty());
    // Deliver only the first packet: the control message must already be
    // in it (strict priority), despite the bulk stream queueing first.
    p.b.handle_packet(p.now, Packet::decode(&out[0]).unwrap()).unwrap();
    let got = Pair::msgs(&mut p.b);
    assert!(
        got.iter().any(|m| m == b"urgent"),
        "control message must ride the first packet ahead of bulk data"
    );
}

/// (c) CUBIC sustains higher throughput than NewReno on the high-BDP
/// bufferbloat scenario (1 Gbps, deep queue, trace loss): after each loss
/// CUBIC climbs back along the cubic curve while NewReno crawls at one
/// MSS per RTT.
#[test]
fn cubic_outperforms_newreno_on_high_bdp() {
    /// Virtual time to push `calls` 256 KB echoes through the bufferbloat
    /// path (bounded work, so the debug-mode crypto cost stays sane).
    fn finish_time(cc: CcAlgorithm, calls: usize) -> Time {
        let (mut world, client, server) = table1_world_cc(NetScenario::Bufferbloat, 7, cc);
        server.borrow_mut().register_service(echo_service(128));
        let server_peer = server.borrow().peer_id();
        // No-retry stub: this measures the transport's recovery, so the
        // RPC layer must not paper over losses.
        let mut stub = Stub::new("bench", vec![server_peer]);
        let body: Buf = vec![0xA7u8; 256 * 1024].into();
        let start = world.net.now();
        let deadline = start + 120 * SECOND;
        let (mut issued, mut done, mut in_flight) = (0usize, 0usize, 0usize);
        while done < calls && world.net.now() < deadline {
            while in_flight < 16 && issued < calls {
                let mut n = client.borrow_mut();
                stub.call(&mut n, &mut world.net, "echo", body.clone());
                issued += 1;
                in_flight += 1;
            }
            world.run_for(5 * MILLI);
            let evs = client.borrow_mut().drain_events();
            {
                let mut n = client.borrow_mut();
                for e in &evs {
                    stub.on_node_event(&mut n, &mut world.net, e);
                }
                stub.tick(&mut n, &mut world.net);
            }
            while let Some(d) = stub.poll_done() {
                in_flight -= 1;
                if d.status == Status::Ok {
                    done += 1;
                }
            }
        }
        assert!(done >= calls * 9 / 10, "{}: only {done}/{calls} completed", cc.name());
        world.net.now() - start
    }
    let cubic = finish_time(CcAlgorithm::Cubic, 48);
    let newreno = finish_time(CcAlgorithm::NewReno, 48);
    assert!(
        cubic < newreno,
        "CUBIC must out-recover NewReno at high BDP: cubic={}ms newreno={}ms",
        cubic / MILLI,
        newreno / MILLI
    );
}
