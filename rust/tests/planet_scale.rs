//! Planet-scale scenario acceptance: lazy materialization keeps large
//! meshes cheap while lookup quality holds, and the scaling-curve gauges
//! (peak queue depth, in-flight payload bytes) are actually populated.
//!
//! Seeded and deterministic. The 1k-node arm is ignored under debug
//! builds and runs in CI's release pass; the 10k and 100k arms live in
//! `benches/dht_lookup.rs` (the 100k one behind `PLANET_100K=1`).

use lattica::scenarios::{planet_scale, PlanetConfig};

#[test]
fn planet_mid_arm_lookups_succeed_and_stay_lazy() {
    let mut o = planet_scale(&PlanetConfig::sized(150, 10, 1106));
    assert_eq!(o.stats.attempted, 10);
    assert!(
        o.stats.success_rate() >= 0.8,
        "mid-arm success collapsed: {:.2} ({:?})",
        o.stats.success_rate(),
        o.stats.summary()
    );
    // Laziness: the measured workload must not wake the whole planet.
    assert!(o.materialized > 0, "no background node ever served traffic");
    assert!(
        (o.materialized as usize) < o.background_total / 2,
        "materialized {}/{} background nodes — laziness broken",
        o.materialized,
        o.background_total
    );
    // The gauges behind the bench rows must be live, not default zeros.
    assert!(o.peak_queue_depth > 0);
    assert!(o.peak_inflight_datagrams > 0);
    assert!(o.peak_inflight_payload_bytes > 0);
    assert!(o.events_processed > 0);
    assert!(o.kad_served > 0, "background responders never answered kad");
    assert!(o.churn_downs + o.churn_ups > 0, "churn plan never fired");
}

#[test]
fn planet_arm_is_deterministic_modulo_wall_clock() {
    let a = planet_scale(&PlanetConfig::sized(120, 8, 77));
    let b = planet_scale(&PlanetConfig::sized(120, 8, 77));
    assert_eq!(a.stats.attempted, b.stats.attempted);
    assert_eq!(a.stats.succeeded, b.stats.succeeded);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.materialized, b.materialized);
    assert_eq!(a.kad_served, b.kad_served);
    assert_eq!(a.peak_queue_depth, b.peak_queue_depth);
}

/// The 1k-node scaling-curve arm with the acceptance bar from the issue:
/// ≥95% lookup success. Heavy — release builds only (CI runs it).
#[test]
#[cfg_attr(debug_assertions, ignore = "release-mode scenario; run via CI or --include-ignored")]
fn planet_1k_success_rate_meets_bar() {
    let mut o = planet_scale(&PlanetConfig::sized(1_000, 40, 2024));
    assert!(
        o.stats.success_rate() >= 0.95,
        "1k-arm success below the 95% bar: {:.3} ({:?})",
        o.stats.success_rate(),
        o.stats.summary()
    );
    assert!(
        (o.materialized as usize) < o.background_total / 4,
        "1k arm materialized {}/{} background nodes",
        o.materialized,
        o.background_total
    );
    assert!(o.events_dropped_stale > 0, "churn never produced stale events");
}
