//! Counting-allocator comparison of the zero-copy data path against a
//! copy-path control (the seed's semantics: fresh encode `Vec` per message,
//! `to_vec()` payload on decode, cloned response payload).
//!
//! One test function only: the counting allocator is process-global and the
//! measurement must not interleave with other tests in this binary.

use lattica::identity::Keypair;
use lattica::netsim::MILLI;
use lattica::rpc::RpcMsg;
use lattica::transport::connection::{ConnEvent, Connection, ConnectionConfig, Role};
use lattica::transport::packet::Packet;
use lattica::transport::TransportProfile;
use lattica::util::buf::Buf;
use lattica::util::Rng;
use lattica::wire::{encode_pooled, Message, PbWriter};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

const M_REQUEST: u64 = 1;
const M_RESPONSE: u64 = 2;

/// Established connection pair driven directly (no simulator).
struct Pair {
    a: Connection,
    b: Connection,
    now: u64,
}

impl Pair {
    fn new() -> Pair {
        let mut rng = Rng::new(7);
        let cfg = ConnectionConfig {
            profile: TransportProfile::QUIC_LIKE,
            ..ConnectionConfig::default()
        };
        let mut a = Connection::new(Role::Client, cfg.clone(), Keypair::from_seed(1), 0, &mut rng);
        let mut b = Connection::new(Role::Server, cfg, Keypair::from_seed(2), 0, &mut rng);
        let mut now = 0u64;
        pump(&mut a, &mut b, &mut now);
        assert!(a.is_established() && b.is_established());
        Pair { a, b, now }
    }
}

fn pump(a: &mut Connection, b: &mut Connection, now: &mut u64) {
    loop {
        *now += MILLI;
        let out_a = a.poll_output(*now);
        let out_b = b.poll_output(*now);
        if out_a.is_empty() && out_b.is_empty() {
            break;
        }
        for p in out_a {
            let pkt = Packet::decode(&p).unwrap();
            b.handle_packet(*now, pkt).unwrap();
        }
        for p in out_b {
            let pkt = Packet::decode(&p).unwrap();
            a.handle_packet(*now, pkt).unwrap();
        }
    }
}

fn drain_msgs(c: &mut Connection) -> Vec<(u64, Buf)> {
    let mut out = Vec::new();
    while let Some(ev) = c.poll_event() {
        if let ConnEvent::Msg { stream_id, msg } = ev {
            out.push((stream_id, msg));
        }
    }
    out
}

/// One unary echo over the live transport, zero-copy path: pooled request
/// encode, zero-copy decode, response shares the request payload.
fn echo_zero_copy(p: &mut Pair, sid: u64, payload: &Buf) {
    let req = RpcMsg {
        kind: M_REQUEST,
        service: "bench".into(),
        method: "echo".into(),
        payload: payload.clone(),
        ..Default::default()
    };
    if req.payload.len() > 512 {
        p.a.send_msg_buf(sid, req.encode_buf()).unwrap();
    } else {
        encode_pooled(&req, |bytes| p.a.send_msg(sid, bytes)).unwrap();
    }
    pump(&mut p.a, &mut p.b, &mut p.now);
    for (msid, msg) in drain_msgs(&mut p.b) {
        let m = RpcMsg::decode_buf(&msg).unwrap();
        let resp = RpcMsg {
            kind: M_RESPONSE,
            payload: m.payload, // zero-copy echo
            ..Default::default()
        };
        let mut w = PbWriter::pooled();
        resp.encode_to(&mut w);
        if resp.payload.len() > 512 {
            p.b.send_msg_buf(msid, Buf::from_vec(w.finish())).unwrap();
        } else {
            p.b.send_msg(msid, &w.buf).unwrap();
            w.recycle();
        }
    }
    pump(&mut p.a, &mut p.b, &mut p.now);
    let got = drain_msgs(&mut p.a);
    assert_eq!(got.len(), 1);
    let m = RpcMsg::decode_buf(&got[0].1).unwrap();
    assert_eq!(m.payload, *payload);
}

/// The same echo with the seed's copy semantics layered on the same
/// transport: fresh encode `Vec`s, `decode` (payload `to_vec`), and a
/// cloned response payload.
fn echo_copy_control(p: &mut Pair, sid: u64, payload: &Buf) {
    let req = RpcMsg {
        kind: M_REQUEST,
        service: "bench".into(),
        method: "echo".into(),
        payload: Buf::copy_from_slice(payload), // caller-owned copy (old `payload.to_vec()`)
        ..Default::default()
    };
    let bytes = req.encode(); // fresh Vec per message
    p.a.send_msg(sid, &bytes).unwrap();
    pump(&mut p.a, &mut p.b, &mut p.now);
    for (msid, msg) in drain_msgs(&mut p.b) {
        let m = RpcMsg::decode(&msg).unwrap(); // payload copied out
        let resp = RpcMsg {
            kind: M_RESPONSE,
            payload: Buf::copy_from_slice(&m.payload), // old respond(&payload) copy
            ..Default::default()
        };
        let bytes = resp.encode();
        p.b.send_msg(msid, &bytes).unwrap();
    }
    pump(&mut p.a, &mut p.b, &mut p.now);
    let got = drain_msgs(&mut p.a);
    assert_eq!(got.len(), 1);
    let m = RpcMsg::decode(&got[0].1).unwrap();
    assert_eq!(m.payload, *payload);
}

#[test]
fn zero_copy_echo_halves_allocations() {
    let payload = Buf::from_vec(vec![0x5Au8; 64 * 1024]);
    const N: u64 = 50;

    // --- Codec layer (encode/decode round, no transport). -------------
    let req = RpcMsg {
        kind: M_REQUEST,
        service: "bench".into(),
        method: "echo".into(),
        payload: payload.clone(),
        ..Default::default()
    };
    // Warm the encoder pool outside the measurement.
    encode_pooled(&req, |_| {});
    let wire = req.encode_buf();

    let before = allocs();
    for _ in 0..N {
        // decode_buf: payload is a slice of `wire`; pooled re-encode.
        let m = RpcMsg::decode_buf(&wire).unwrap();
        encode_pooled(&m, |_| {});
    }
    let codec_new = allocs() - before;

    let before = allocs();
    for _ in 0..N {
        // Control: payload copied out; fresh encode Vec.
        let m = RpcMsg::decode(&wire).unwrap();
        let _ = m.encode();
    }
    let codec_control = allocs() - before;

    println!("codec allocs/call: zero-copy {} vs control {}", codec_new / N, codec_control / N);
    assert!(
        codec_new * 2 <= codec_control,
        "codec path must at least halve allocations: {codec_new} vs {codec_control}"
    );

    // --- Full transport echo (fragmentation, AEAD, reassembly). -------
    let mut p = Pair::new();
    let sid = p.a.open_stream("/bench/echo/zc");
    let sid2 = p.a.open_stream("/bench/echo/ctl");
    // Warm up both paths (stream setup, maps, pool).
    echo_zero_copy(&mut p, sid, &payload);
    echo_copy_control(&mut p, sid2, &payload);

    let before = allocs();
    for _ in 0..N {
        echo_zero_copy(&mut p, sid, &payload);
    }
    let full_new = allocs() - before;

    let before = allocs();
    for _ in 0..N {
        echo_copy_control(&mut p, sid2, &payload);
    }
    let full_control = allocs() - before;

    println!("full-path allocs/call: zero-copy {} vs control {}", full_new / N, full_control / N);
    // The full path still pays per-packet datagram allocations on the
    // simulated wire (shared by both variants), so the end-to-end bound is
    // directional: the zero-copy path must allocate strictly less, by at
    // least the per-call copies the control performs (2 payload copies +
    // 2 decode copies per echo).
    assert!(
        full_new + 2 * N <= full_control,
        "transport echo must drop the per-call payload copies: {full_new} vs {full_control}"
    );
}
