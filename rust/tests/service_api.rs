//! Acceptance tests for the typed service layer: router dispatch with
//! error details, wire-propagated deadlines (expired requests never reach
//! a handler; nested calls inherit the shrunken budget), stub failover
//! across replicas, and hedged calls with cancel-on-first-win.
//!
//! Run in CI as `cargo test --release --test service_api`.

use lattica::netsim::topology::LinkProfile;
use lattica::netsim::{MILLI, SECOND};
use lattica::node::{run_until, App, LatticaNode, NodeEvent};
use lattica::protocols::Ctx;
use lattica::rpc::{
    CallOptions, HedgePolicy, Outcome, Reply, RetryPolicy, RpcEvent, Service, Status, Stub,
};
use lattica::runtime::Tensor;
use lattica::scenarios::{
    bootstrap_mesh, drain, echo_service, peer_of, stub_call_blocking, table1_world, NetScenario,
};
use lattica::shard::{PipelineClient, ShardRequest, SHARD_SERVICE};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

#[test]
fn router_dispatch_and_error_detail_ride_the_wire() {
    let (mut world, client, server) = table1_world(NetScenario::SameRegionLan, 21);
    let server_peer = server.borrow().peer_id();
    server.borrow_mut().register_service(
        Service::new("calc")
            .unary("double", |_node, _net, _ctx, payload| {
                let out: Vec<u8> = payload.iter().flat_map(|b| [*b, *b]).collect();
                Outcome::reply(out)
            })
            .unary("boom", |_node, _net, _ctx, _payload| {
                Outcome::fail(Status::Error, "kaboom: cache poisoned")
            }),
    );

    let mut stub = Stub::new("calc", vec![server_peer]);
    let done = stub_call_blocking(&mut world, &client, &mut stub, "double", b"ab", 5 * SECOND)
        .expect("double completes");
    assert_eq!(done.status, Status::Ok);
    assert_eq!(done.payload, b"aabb");

    // A handler failure surfaces its detail string at the caller, not a
    // bare status code.
    let done = stub_call_blocking(&mut world, &client, &mut stub, "boom", b"", 5 * SECOND)
        .expect("boom completes");
    assert_eq!(done.status, Status::Error);
    assert_eq!(done.detail, "kaboom: cache poisoned");

    // Unknown method / unknown service answer NotFound with context.
    let done = stub_call_blocking(&mut world, &client, &mut stub, "nope", b"", 5 * SECOND)
        .expect("nope completes");
    assert_eq!(done.status, Status::NotFound);
    assert!(done.detail.contains("unknown method"), "detail: {}", done.detail);

    let mut ghost = Stub::new("ghost", vec![server_peer]);
    let done = stub_call_blocking(&mut world, &client, &mut ghost, "x", b"", 5 * SECOND)
        .expect("ghost completes");
    assert_eq!(done.status, Status::NotFound);
    assert!(done.detail.contains("unknown service"), "detail: {}", done.detail);

    let stats = server.borrow().router_stats();
    assert_eq!(stats.served, 1);
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.unknown_method, 1);
    assert_eq!(stats.unknown_service, 1);
}

#[test]
fn expired_request_never_reaches_the_handler() {
    // 75 ms one-way: a 50 ms budget is spent before the request lands.
    let (mut world, client, server) = table1_world(NetScenario::InterContinent, 23);
    let server_peer = server.borrow().peer_id();
    let hits = Rc::new(RefCell::new(0u64));
    {
        let hits = hits.clone();
        server.borrow_mut().register_service(Service::new("slowpath").unary(
            "work",
            move |_node, _net, _ctx, _payload| {
                *hits.borrow_mut() += 1;
                Outcome::reply(&b"done"[..])
            },
        ));
    }

    let mut stub = Stub::new("slowpath", vec![server_peer]).with_options(CallOptions {
        deadline: 50 * MILLI,
        ..CallOptions::default()
    });
    let done = stub_call_blocking(&mut world, &client, &mut stub, "work", b"", 5 * SECOND)
        .expect("op must finish locally at its deadline");
    assert_eq!(done.status, Status::Unavailable);
    assert!(done.detail.contains("deadline"), "detail: {}", done.detail);

    // Let the (already expired) request finish its flight to the server.
    world.run_for(2 * SECOND);
    assert_eq!(*hits.borrow(), 0, "handler must not run for an expired request");
    assert!(
        server.borrow().rpc.expired_dropped >= 1,
        "server must count the expired drop"
    );
    assert_eq!(server.borrow().router_stats().served, 0);

    // The same service under a sane budget works fine — the drop above
    // was deadline enforcement, not a broken path.
    stub.opts.deadline = 5 * SECOND;
    let done = stub_call_blocking(&mut world, &client, &mut stub, "work", b"", 10 * SECOND)
        .expect("op completes");
    assert_eq!(done.status, Status::Ok);
    assert_eq!(*hits.borrow(), 1);
}

#[test]
fn nested_calls_inherit_the_shrunken_budget() {
    let (mut world, nodes) = bootstrap_mesh(3, 71, LinkProfile::DATACENTER);
    let (a, b, c) = (nodes[0].clone(), nodes[1].clone(), nodes[2].clone());
    let b_peer = peer_of(&b);
    let c_peer = peer_of(&c);
    // B relays to C, so it needs its own connection.
    let c_ma = c.borrow().listen_addr();
    b.borrow_mut().dial(&mut world.net, &c_ma).unwrap();
    assert!(run_until(&mut world, 5 * SECOND, || b
        .borrow()
        .swarm
        .is_connected(&c_peer)));

    let deadline_at_b = Rc::new(RefCell::new(0u64));
    let deadline_at_c = Rc::new(RefCell::new(0u64));
    let remaining_at_c = Rc::new(RefCell::new(0u64));
    {
        let dc = deadline_at_c.clone();
        let rc = remaining_at_c.clone();
        c.borrow_mut().register_service(Service::new("inner").unary(
            "probe",
            move |_node, net, ctx, _payload| {
                *dc.borrow_mut() = ctx.deadline;
                *rc.borrow_mut() = ctx.remaining(net.now());
                Outcome::reply(&b"pong"[..])
            },
        ));
    }
    // B's outer handler defers its reply and issues a nested call whose
    // budget is whatever remains of the inbound deadline.
    let pending: Rc<RefCell<HashMap<u64, Reply>>> = Rc::new(RefCell::new(HashMap::new()));
    {
        let db = deadline_at_b.clone();
        let pending = pending.clone();
        b.borrow_mut().register_service(Service::new("outer").unary(
            "relay",
            move |node, net, ctx, _payload| {
                *db.borrow_mut() = ctx.deadline;
                let budget = ctx.remaining(net.now());
                let res = {
                    let LatticaNode { swarm, rpc, .. } = node;
                    let mut c2 = Ctx::new(swarm, net);
                    rpc.call_opts(&mut c2, &c_peer, "inner", "probe", &b"ping"[..], budget)
                };
                match res {
                    Ok(call_id) => {
                        pending.borrow_mut().insert(call_id, ctx.reply_handle());
                        Outcome::Deferred
                    }
                    Err(e) => Outcome::fail(Status::Error, e.to_string()),
                }
            },
        ));
    }
    // Thin raw-event adapter: resolve the deferred reply when the nested
    // call completes (the one legitimate App job left).
    struct Resolver {
        pending: Rc<RefCell<HashMap<u64, Reply>>>,
    }
    impl App for Resolver {
        fn handle(
            &mut self,
            node: &mut LatticaNode,
            net: &mut lattica::netsim::Net,
            ev: NodeEvent,
        ) -> Option<NodeEvent> {
            if let NodeEvent::Rpc(RpcEvent::Response {
                call_id,
                status,
                payload,
                detail,
                ..
            }) = &ev
            {
                if let Some(reply) = self.pending.borrow_mut().remove(call_id) {
                    let _ = reply.send(node, net, *status, payload.clone(), detail);
                    return None;
                }
            }
            Some(ev)
        }
    }
    b.borrow_mut().app = Some(Box::new(Resolver {
        pending: pending.clone(),
    }));

    let t0 = world.net.now();
    let mut stub = Stub::new("outer", vec![b_peer]).with_options(CallOptions {
        deadline: 5 * SECOND,
        ..CallOptions::default()
    });
    let done = stub_call_blocking(&mut world, &a, &mut stub, "relay", b"x", 10 * SECOND)
        .expect("relay completes");
    assert_eq!(done.status, Status::Ok, "detail: {}", done.detail);
    assert_eq!(done.payload, b"pong");

    let db = *deadline_at_b.borrow();
    let dc = *deadline_at_c.borrow();
    let rem_c = *remaining_at_c.borrow();
    assert_eq!(db, t0 + 5 * SECOND, "B observes the client's absolute deadline");
    assert_eq!(dc, db, "nested call inherits the same absolute deadline");
    assert!(
        rem_c > 0 && rem_c < 5 * SECOND,
        "C's remaining budget must have shrunk by transit/handling time (got {rem_c})"
    );
}

/// Kill the preferred stage-0 replica mid-pipeline: the stage stub's
/// failover must complete every request via the fallback replica (the
/// "DHT-based failover" the shard docs promise).
#[test]
fn pipeline_failover_completes_via_fallback_replica() {
    let (mut world, nodes) = bootstrap_mesh(5, 77, LinkProfile::DATACENTER);
    let client = nodes[0].clone();
    let stages = vec![
        vec![peer_of(&nodes[1]), peer_of(&nodes[2])],
        vec![peer_of(&nodes[3]), peer_of(&nodes[4])],
    ];
    for (i, nd) in nodes[1..].iter().enumerate() {
        let stage = i / 2;
        nd.borrow_mut().register_service(Service::new(SHARD_SERVICE).unary(
            "forward",
            move |_node, _net, _ctx, payload| match ShardRequest::decode(&payload) {
                Ok(req) => {
                    let t = Tensor::from_f32(&[1, 2], &[stage as f32, req.request_id as f32]);
                    Outcome::reply(t.encode())
                }
                Err(e) => Outcome::fail(Status::Error, e.to_string()),
            },
        ));
    }
    world.run_for(SECOND);

    let mut pipeline = PipelineClient::new(stages);
    let tokens: Vec<i32> = (0..8).collect();
    let run_to = |world: &mut lattica::netsim::World, pipeline: &mut PipelineClient, want: usize| {
        let deadline = world.net.now() + 60 * SECOND;
        while pipeline.completed.len() < want && world.net.now() < deadline {
            world.run_for(20 * MILLI);
            let evs = drain(&client);
            let mut c = client.borrow_mut();
            for e in &evs {
                if let NodeEvent::Rpc(ev) = e {
                    pipeline.on_rpc_event(&mut c, &mut world.net, ev);
                }
            }
            pipeline.tick(&mut c, &mut world.net);
        }
    };

    // Healthy phase.
    for _ in 0..2 {
        let mut c = client.borrow_mut();
        pipeline.infer(&mut c, &mut world.net, tokens.clone()).unwrap();
    }
    run_to(&mut world, &mut pipeline, 2);
    assert_eq!(pipeline.completed.len(), 2);

    // Kill the preferred stage-0 replica, then keep serving.
    let dead = nodes[1].borrow().endpoint_id();
    world.remove_endpoint(dead);
    for _ in 0..2 {
        let mut c = client.borrow_mut();
        pipeline.infer(&mut c, &mut world.net, tokens.clone()).unwrap();
    }
    run_to(&mut world, &mut pipeline, 4);

    assert_eq!(pipeline.completed.len(), 4, "failover must mask the dead replica");
    assert!(pipeline.failed.is_empty(), "failed: {:?}", pipeline.failed);
    assert!(
        pipeline.stage_stats(0).failovers >= 1,
        "stage-0 stub must have failed over: {}",
        pipeline.stage_stats(0).summary()
    );
}

/// A replica that *serves* errors (stale params, local corruption) must
/// not fail the request while a healthy sibling exists — the pipeline's
/// retry policy opts into failover on `Status::Error`.
#[test]
fn pipeline_fails_over_on_served_errors() {
    let (mut world, nodes) = bootstrap_mesh(3, 79, LinkProfile::DATACENTER);
    let client = nodes[0].clone();
    nodes[1].borrow_mut().register_service(Service::new(SHARD_SERVICE).unary(
        "forward",
        |_node, _net, _ctx, _payload| Outcome::fail(Status::Error, "stale parameters"),
    ));
    nodes[2].borrow_mut().register_service(Service::new(SHARD_SERVICE).unary(
        "forward",
        |_node, _net, _ctx, _payload| {
            Outcome::reply(Tensor::from_f32(&[1, 2], &[1.0, 2.0]).encode())
        },
    ));
    world.run_for(SECOND);

    let mut pipeline = PipelineClient::new(vec![vec![peer_of(&nodes[1]), peer_of(&nodes[2])]]);
    {
        let mut c = client.borrow_mut();
        pipeline.infer(&mut c, &mut world.net, vec![1, 2, 3]).unwrap();
    }
    let deadline = world.net.now() + 30 * SECOND;
    while pipeline.completed.is_empty() && world.net.now() < deadline {
        world.run_for(20 * MILLI);
        let evs = drain(&client);
        let mut c = client.borrow_mut();
        for e in &evs {
            if let NodeEvent::Rpc(ev) = e {
                pipeline.on_rpc_event(&mut c, &mut world.net, ev);
            }
        }
        pipeline.tick(&mut c, &mut world.net);
    }
    assert_eq!(
        pipeline.completed.len(),
        1,
        "served-error failover must mask the bad replica: {:?}",
        pipeline.failed
    );
    assert!(pipeline.failed.is_empty());
    assert!(pipeline.stage_stats(0).failovers >= 1);
}

#[test]
fn hedged_calls_win_and_cancel_losers() {
    let (mut world, client, server) = table1_world(NetScenario::LossyWan, 123);
    let server_peer = server.borrow().peer_id();
    server.borrow_mut().register_service(echo_service(64));

    let mut stub = Stub::new("bench", vec![server_peer]).with_options(CallOptions {
        deadline: 5 * SECOND,
        attempt_timeout: Some(2 * SECOND),
        retry: RetryPolicy::idempotent(),
        hedge: HedgePolicy::on(),
    });
    let mut ok = 0;
    for i in 0..30u8 {
        let done =
            stub_call_blocking(&mut world, &client, &mut stub, "echo", vec![i; 64], 10 * SECOND)
                .expect("op completes");
        if done.status == Status::Ok {
            ok += 1;
        }
    }
    assert_eq!(ok, 30, "stats: {}", stub.stats.summary());
    // The initial hedge delay (100 ms) is below the 150 ms RTT, so the
    // first ops must have hedged; every losing attempt was cancelled.
    assert!(stub.stats.hedges > 0, "stats: {}", stub.stats.summary());
    assert!(stub.stats.cancelled > 0, "stats: {}", stub.stats.summary());
    world.run_for(SECOND);
    assert_eq!(
        client.borrow().rpc.pending_calls(),
        0,
        "losing hedges must be cancelled, not leaked"
    );
}
