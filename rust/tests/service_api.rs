//! Acceptance tests for the typed service layer: router dispatch with
//! error details, wire-propagated deadlines (expired requests never reach
//! a handler; nested calls inherit the shrunken budget), stub failover
//! across replicas, and hedged calls with cancel-on-first-win.
//!
//! Run in CI as `cargo test --release --test service_api`.

use lattica::netsim::topology::LinkProfile;
use lattica::netsim::{MILLI, SECOND};
use lattica::node::{run_until, App, LatticaNode, NodeEvent};
use lattica::protocols::Ctx;
use lattica::rpc::{
    AdmissionPolicy, CallOptions, HedgePolicy, Outcome, Reply, RetryPolicy, RpcEvent, Service,
    Status, Stub, StubDone,
};
use lattica::runtime::Tensor;
use lattica::scenarios::{
    bootstrap_mesh, drain, echo_service, overload_scenario, peer_of, stub_call_blocking,
    table1_world, NetScenario, Node, OverloadConfig,
};
use lattica::shard::{PipelineClient, ShardRequest, SHARD_SERVICE};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

#[test]
fn router_dispatch_and_error_detail_ride_the_wire() {
    let (mut world, client, server) = table1_world(NetScenario::SameRegionLan, 21);
    let server_peer = server.borrow().peer_id();
    server.borrow_mut().register_service(
        Service::new("calc")
            .unary("double", |_node, _net, _ctx, payload| {
                let out: Vec<u8> = payload.iter().flat_map(|b| [*b, *b]).collect();
                Outcome::reply(out)
            })
            .unary("boom", |_node, _net, _ctx, _payload| {
                Outcome::fail(Status::Error, "kaboom: cache poisoned")
            }),
    );

    let mut stub = Stub::new("calc", vec![server_peer]);
    let done = stub_call_blocking(&mut world, &client, &mut stub, "double", b"ab", 5 * SECOND)
        .expect("double completes");
    assert_eq!(done.status, Status::Ok);
    assert_eq!(done.payload, b"aabb");

    // A handler failure surfaces its detail string at the caller, not a
    // bare status code.
    let done = stub_call_blocking(&mut world, &client, &mut stub, "boom", b"", 5 * SECOND)
        .expect("boom completes");
    assert_eq!(done.status, Status::Error);
    assert_eq!(done.detail, "kaboom: cache poisoned");

    // Unknown method / unknown service answer NotFound with context.
    let done = stub_call_blocking(&mut world, &client, &mut stub, "nope", b"", 5 * SECOND)
        .expect("nope completes");
    assert_eq!(done.status, Status::NotFound);
    assert!(done.detail.contains("unknown method"), "detail: {}", done.detail);

    let mut ghost = Stub::new("ghost", vec![server_peer]);
    let done = stub_call_blocking(&mut world, &client, &mut ghost, "x", b"", 5 * SECOND)
        .expect("ghost completes");
    assert_eq!(done.status, Status::NotFound);
    assert!(done.detail.contains("unknown service"), "detail: {}", done.detail);

    let stats = server.borrow().router_stats();
    assert_eq!(stats.served, 1);
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.unknown_method, 1);
    assert_eq!(stats.unknown_service, 1);
}

#[test]
fn expired_request_never_reaches_the_handler() {
    // 75 ms one-way: a 50 ms budget is spent before the request lands.
    let (mut world, client, server) = table1_world(NetScenario::InterContinent, 23);
    let server_peer = server.borrow().peer_id();
    let hits = Rc::new(RefCell::new(0u64));
    {
        let hits = hits.clone();
        server.borrow_mut().register_service(Service::new("slowpath").unary(
            "work",
            move |_node, _net, _ctx, _payload| {
                *hits.borrow_mut() += 1;
                Outcome::reply(&b"done"[..])
            },
        ));
    }

    let mut stub = Stub::new("slowpath", vec![server_peer]).with_options(CallOptions {
        deadline: 50 * MILLI,
        ..CallOptions::default()
    });
    let done = stub_call_blocking(&mut world, &client, &mut stub, "work", b"", 5 * SECOND)
        .expect("op must finish locally at its deadline");
    assert_eq!(done.status, Status::Unavailable);
    assert!(done.detail.contains("deadline"), "detail: {}", done.detail);

    // Let the (already expired) request finish its flight to the server.
    world.run_for(2 * SECOND);
    assert_eq!(*hits.borrow(), 0, "handler must not run for an expired request");
    assert!(
        server.borrow().rpc.expired_dropped >= 1,
        "server must count the expired drop"
    );
    assert_eq!(server.borrow().router_stats().served, 0);

    // The same service under a sane budget works fine — the drop above
    // was deadline enforcement, not a broken path.
    stub.opts.deadline = 5 * SECOND;
    let done = stub_call_blocking(&mut world, &client, &mut stub, "work", b"", 10 * SECOND)
        .expect("op completes");
    assert_eq!(done.status, Status::Ok);
    assert_eq!(*hits.borrow(), 1);
}

#[test]
fn nested_calls_inherit_the_shrunken_budget() {
    let (mut world, nodes) = bootstrap_mesh(3, 71, LinkProfile::DATACENTER);
    let (a, b, c) = (nodes[0].clone(), nodes[1].clone(), nodes[2].clone());
    let b_peer = peer_of(&b);
    let c_peer = peer_of(&c);
    // B relays to C, so it needs its own connection.
    let c_ma = c.borrow().listen_addr();
    b.borrow_mut().dial(&mut world.net, &c_ma).unwrap();
    assert!(run_until(&mut world, 5 * SECOND, || b
        .borrow()
        .swarm
        .is_connected(&c_peer)));

    let deadline_at_b = Rc::new(RefCell::new(0u64));
    let deadline_at_c = Rc::new(RefCell::new(0u64));
    let remaining_at_c = Rc::new(RefCell::new(0u64));
    {
        let dc = deadline_at_c.clone();
        let rc = remaining_at_c.clone();
        c.borrow_mut().register_service(Service::new("inner").unary(
            "probe",
            move |_node, net, ctx, _payload| {
                *dc.borrow_mut() = ctx.deadline;
                *rc.borrow_mut() = ctx.remaining(net.now());
                Outcome::reply(&b"pong"[..])
            },
        ));
    }
    // B's outer handler defers its reply and issues a nested call whose
    // budget is whatever remains of the inbound deadline.
    let pending: Rc<RefCell<HashMap<u64, Reply>>> = Rc::new(RefCell::new(HashMap::new()));
    {
        let db = deadline_at_b.clone();
        let pending = pending.clone();
        b.borrow_mut().register_service(Service::new("outer").unary(
            "relay",
            move |node, net, ctx, _payload| {
                *db.borrow_mut() = ctx.deadline;
                let budget = ctx.remaining(net.now());
                let res = {
                    let LatticaNode { swarm, rpc, .. } = node;
                    let mut c2 = Ctx::new(swarm, net);
                    rpc.call_opts(&mut c2, &c_peer, "inner", "probe", &b"ping"[..], budget)
                };
                match res {
                    Ok(call_id) => {
                        pending.borrow_mut().insert(call_id, ctx.reply_handle());
                        Outcome::Deferred
                    }
                    Err(e) => Outcome::fail(Status::Error, e.to_string()),
                }
            },
        ));
    }
    // Thin raw-event adapter: resolve the deferred reply when the nested
    // call completes (the one legitimate App job left).
    struct Resolver {
        pending: Rc<RefCell<HashMap<u64, Reply>>>,
    }
    impl App for Resolver {
        fn handle(
            &mut self,
            node: &mut LatticaNode,
            net: &mut lattica::netsim::Net,
            ev: NodeEvent,
        ) -> Option<NodeEvent> {
            if let NodeEvent::Rpc(RpcEvent::Response {
                call_id,
                status,
                payload,
                detail,
                ..
            }) = &ev
            {
                if let Some(reply) = self.pending.borrow_mut().remove(call_id) {
                    let _ = reply.send(node, net, *status, payload.clone(), detail);
                    return None;
                }
            }
            Some(ev)
        }
    }
    b.borrow_mut().app = Some(Box::new(Resolver {
        pending: pending.clone(),
    }));

    let t0 = world.net.now();
    let mut stub = Stub::new("outer", vec![b_peer]).with_options(CallOptions {
        deadline: 5 * SECOND,
        ..CallOptions::default()
    });
    let done = stub_call_blocking(&mut world, &a, &mut stub, "relay", b"x", 10 * SECOND)
        .expect("relay completes");
    assert_eq!(done.status, Status::Ok, "detail: {}", done.detail);
    assert_eq!(done.payload, b"pong");

    let db = *deadline_at_b.borrow();
    let dc = *deadline_at_c.borrow();
    let rem_c = *remaining_at_c.borrow();
    assert_eq!(db, t0 + 5 * SECOND, "B observes the client's absolute deadline");
    assert_eq!(dc, db, "nested call inherits the same absolute deadline");
    assert!(
        rem_c > 0 && rem_c < 5 * SECOND,
        "C's remaining budget must have shrunk by transit/handling time (got {rem_c})"
    );
}

/// Kill the preferred stage-0 replica mid-pipeline: the stage stub's
/// failover must complete every request via the fallback replica (the
/// "DHT-based failover" the shard docs promise).
#[test]
fn pipeline_failover_completes_via_fallback_replica() {
    let (mut world, nodes) = bootstrap_mesh(5, 77, LinkProfile::DATACENTER);
    let client = nodes[0].clone();
    let stages = vec![
        vec![peer_of(&nodes[1]), peer_of(&nodes[2])],
        vec![peer_of(&nodes[3]), peer_of(&nodes[4])],
    ];
    for (i, nd) in nodes[1..].iter().enumerate() {
        let stage = i / 2;
        nd.borrow_mut().register_service(Service::new(SHARD_SERVICE).unary(
            "forward",
            move |_node, _net, _ctx, payload| match ShardRequest::decode(&payload) {
                Ok(req) => {
                    let t = Tensor::from_f32(&[1, 2], &[stage as f32, req.request_id as f32]);
                    Outcome::reply(t.encode())
                }
                Err(e) => Outcome::fail(Status::Error, e.to_string()),
            },
        ));
    }
    world.run_for(SECOND);

    let mut pipeline = PipelineClient::new(stages);
    let tokens: Vec<i32> = (0..8).collect();
    let run_to = |world: &mut lattica::netsim::World, pipeline: &mut PipelineClient, want: usize| {
        let deadline = world.net.now() + 60 * SECOND;
        while pipeline.completed.len() < want && world.net.now() < deadline {
            world.run_for(20 * MILLI);
            let evs = drain(&client);
            let mut c = client.borrow_mut();
            for e in &evs {
                if let NodeEvent::Rpc(ev) = e {
                    pipeline.on_rpc_event(&mut c, &mut world.net, ev);
                }
            }
            pipeline.tick(&mut c, &mut world.net);
        }
    };

    // Healthy phase.
    for _ in 0..2 {
        let mut c = client.borrow_mut();
        pipeline.infer(&mut c, &mut world.net, tokens.clone()).unwrap();
    }
    run_to(&mut world, &mut pipeline, 2);
    assert_eq!(pipeline.completed.len(), 2);

    // Kill the preferred stage-0 replica, then keep serving.
    let dead = nodes[1].borrow().endpoint_id();
    world.remove_endpoint(dead);
    for _ in 0..2 {
        let mut c = client.borrow_mut();
        pipeline.infer(&mut c, &mut world.net, tokens.clone()).unwrap();
    }
    run_to(&mut world, &mut pipeline, 4);

    assert_eq!(pipeline.completed.len(), 4, "failover must mask the dead replica");
    assert!(pipeline.failed.is_empty(), "failed: {:?}", pipeline.failed);
    assert!(
        pipeline.stage_stats(0).failovers >= 1,
        "stage-0 stub must have failed over: {}",
        pipeline.stage_stats(0).summary()
    );
}

/// A replica that *serves* errors (stale params, local corruption) must
/// not fail the request while a healthy sibling exists — the pipeline's
/// retry policy opts into failover on `Status::Error`.
#[test]
fn pipeline_fails_over_on_served_errors() {
    let (mut world, nodes) = bootstrap_mesh(3, 79, LinkProfile::DATACENTER);
    let client = nodes[0].clone();
    nodes[1].borrow_mut().register_service(Service::new(SHARD_SERVICE).unary(
        "forward",
        |_node, _net, _ctx, _payload| Outcome::fail(Status::Error, "stale parameters"),
    ));
    nodes[2].borrow_mut().register_service(Service::new(SHARD_SERVICE).unary(
        "forward",
        |_node, _net, _ctx, _payload| {
            Outcome::reply(Tensor::from_f32(&[1, 2], &[1.0, 2.0]).encode())
        },
    ));
    world.run_for(SECOND);

    let mut pipeline = PipelineClient::new(vec![vec![peer_of(&nodes[1]), peer_of(&nodes[2])]]);
    {
        let mut c = client.borrow_mut();
        pipeline.infer(&mut c, &mut world.net, vec![1, 2, 3]).unwrap();
    }
    let deadline = world.net.now() + 30 * SECOND;
    while pipeline.completed.is_empty() && world.net.now() < deadline {
        world.run_for(20 * MILLI);
        let evs = drain(&client);
        let mut c = client.borrow_mut();
        for e in &evs {
            if let NodeEvent::Rpc(ev) = e {
                pipeline.on_rpc_event(&mut c, &mut world.net, ev);
            }
        }
        pipeline.tick(&mut c, &mut world.net);
    }
    assert_eq!(
        pipeline.completed.len(),
        1,
        "served-error failover must mask the bad replica: {:?}",
        pipeline.failed
    );
    assert!(pipeline.failed.is_empty());
    assert!(pipeline.stage_stats(0).failovers >= 1);
}

#[test]
fn hedged_calls_win_and_cancel_losers() {
    let (mut world, client, server) = table1_world(NetScenario::LossyWan, 123);
    let server_peer = server.borrow().peer_id();
    server.borrow_mut().register_service(echo_service(64));

    let mut stub = Stub::new("bench", vec![server_peer]).with_options(CallOptions {
        deadline: 5 * SECOND,
        attempt_timeout: Some(2 * SECOND),
        retry: RetryPolicy::idempotent(),
        hedge: HedgePolicy::on(),
    });
    let mut ok = 0;
    for i in 0..30u8 {
        let done =
            stub_call_blocking(&mut world, &client, &mut stub, "echo", vec![i; 64], 10 * SECOND)
                .expect("op completes");
        if done.status == Status::Ok {
            ok += 1;
        }
    }
    assert_eq!(ok, 30, "stats: {}", stub.stats.summary());
    // The initial hedge delay (100 ms) is below the 150 ms RTT, so the
    // first ops must have hedged; every losing attempt was cancelled.
    assert!(stub.stats.hedges > 0, "stats: {}", stub.stats.summary());
    assert!(stub.stats.cancelled > 0, "stats: {}", stub.stats.summary());
    world.run_for(SECOND);
    assert_eq!(
        client.borrow().rpc.pending_calls(),
        0,
        "losing hedges must be cancelled, not leaked"
    );
}

// ---------------------------------------------------------------------------
// Overload survival: admission control, pushback, orphaned replies.
// (Deadline-aware drop and WFQ semantics are unit-tested on
// `ServiceQueue` in `rpc/queue.rs`; the end-to-end composition is the
// release-gated metastable scenario below.)
// ---------------------------------------------------------------------------

/// Drive the world until every in-flight op of `stub` completes (or
/// `timeout` virtual time passes); returns the completions.
fn drive_until_idle(
    world: &mut lattica::netsim::World,
    node: &Node,
    stub: &mut Stub,
    timeout: u64,
) -> Vec<StubDone> {
    let deadline = world.net.now() + timeout;
    let mut out = Vec::new();
    while stub.in_flight() > 0 && world.net.now() < deadline {
        world.run_for(MILLI);
        let evs = drain(node);
        let mut n = node.borrow_mut();
        for ev in &evs {
            stub.on_node_event(&mut n, &mut world.net, ev);
        }
        stub.tick(&mut n, &mut world.net);
        drop(n);
        while let Some(d) = stub.poll_done() {
            out.push(d);
        }
    }
    out
}

/// Once pushback has been seen, a permanently-shedding target gets at
/// most one wire attempt per logical call — no retry-in-place against a
/// server that already said no.
#[test]
fn overloaded_target_receives_at_most_one_attempt_per_call_after_pushback() {
    let (mut world, client, server) = table1_world(NetScenario::SameRegionLan, 31);
    let server_peer = server.borrow().peer_id();
    // rate 0 sheds everything; the pinned 2 s hint outlives any 1 s call
    // budget, so a well-behaved stub must not keep knocking.
    server.borrow_mut().register_service(
        Service::new("perma")
            .with_admission(AdmissionPolicy::rate(0.0, 0.0).with_retry_after(2 * SECOND))
            .unary("work", |_node, _net, _ctx, _payload| Outcome::reply(&b"never"[..])),
    );

    let mut stub = Stub::new("perma", vec![server_peer]).with_options(CallOptions {
        deadline: SECOND,
        retry: RetryPolicy {
            max_attempts: 3,
            base_backoff: 10 * MILLI,
            max_backoff: 200 * MILLI,
            jitter: 0.0,
            retry_on_error: false,
        },
        ..CallOptions::default()
    });
    let d1 = stub_call_blocking(&mut world, &client, &mut stub, "work", b"", 5 * SECOND)
        .expect("first call completes");
    assert_eq!(d1.status, Status::Overloaded, "detail: {}", d1.detail);
    assert_eq!(
        d1.attempts, 1,
        "the attempt that taught us the target is shedding is the only one"
    );

    // The pushback window (2 s) is still open and exceeds the budget:
    // the second call must fail fast with ZERO wire attempts.
    let d2 = stub_call_blocking(&mut world, &client, &mut stub, "work", b"", 5 * SECOND)
        .expect("second call completes");
    assert_eq!(d2.status, Status::Overloaded);
    assert_eq!(d2.attempts, 0, "no wire attempt while the hint covers the budget");
    assert!(stub.stats.overloaded >= 1, "stats: {}", stub.stats.summary());

    // Server side: exactly one request was ever shed (one wire attempt
    // total), none decoded, none dispatched.
    let srv = server.borrow();
    assert_eq!(srv.rpc.admission.stats.shed_predecode, 1);
    assert_eq!(srv.rpc.requests_decoded, 0);
    assert_eq!(srv.router_stats().served, 0);
}

/// Admission rejection happens from the request header: shed requests
/// never have their payload decoded (counter-pinned).
#[test]
fn pre_decode_rejection_skips_payload_decode() {
    let (mut world, client, server) = table1_world(NetScenario::SameRegionLan, 33);
    let server_peer = server.borrow().peer_id();
    // Burst of 2, negligible refill: of 4 back-to-back calls, exactly 2
    // are admitted and 2 are shed before decode.
    server.borrow_mut().register_service(
        Service::new("bench")
            .with_admission(AdmissionPolicy::rate(0.001, 2.0))
            .unary("echo", |_node, _net, _ctx, payload| Outcome::Reply(payload)),
    );

    let mut stub = Stub::new("bench", vec![server_peer]).with_options(CallOptions {
        deadline: 2 * SECOND,
        ..CallOptions::default()
    });
    {
        let mut n = client.borrow_mut();
        for _ in 0..4 {
            stub.call(&mut n, &mut world.net, "echo", vec![7u8; 256]);
        }
    }
    let done = drive_until_idle(&mut world, &client, &mut stub, 10 * SECOND);
    assert_eq!(done.len(), 4);
    let ok = done.iter().filter(|d| d.status == Status::Ok).count();
    let shed = done.iter().filter(|d| d.status == Status::Overloaded).count();
    assert_eq!((ok, shed), (2, 2), "stats: {}", stub.stats.summary());

    let srv = server.borrow();
    assert_eq!(
        srv.rpc.requests_decoded, 2,
        "shed requests must not reach payload decode"
    );
    assert_eq!(srv.rpc.admission.stats.shed_predecode, 2);
    assert_eq!(srv.router_stats().shed_predecode, 2, "stats overlay");
    assert_eq!(srv.router_stats().served, 2);
}

/// A handler that drops its reply handle without responding must not
/// leave the caller waiting out its deadline: the node answers
/// `Unavailable("reply dropped")` on its behalf and the stub fails over.
#[test]
fn dropped_reply_fails_fast_and_fails_over() {
    let (mut world, nodes) = bootstrap_mesh(3, 83, LinkProfile::DATACENTER);
    let client = nodes[0].clone();
    // Replica 1 takes the reply handle and leaks it; replica 2 is healthy.
    nodes[1].borrow_mut().register_service(Service::new("flaky").unary(
        "work",
        |_node, _net, ctx, _payload| {
            let _ = ctx.reply_handle();
            Outcome::Deferred
        },
    ));
    nodes[2].borrow_mut().register_service(Service::new("flaky").unary(
        "work",
        |_node, _net, _ctx, _payload| Outcome::reply(&b"served"[..]),
    ));
    world.run_for(SECOND);

    let mut stub =
        Stub::new("flaky", vec![peer_of(&nodes[1]), peer_of(&nodes[2])]).with_options(CallOptions {
            deadline: 10 * SECOND,
            retry: RetryPolicy {
                max_attempts: 2,
                base_backoff: 10 * MILLI,
                max_backoff: 100 * MILLI,
                jitter: 0.0,
                retry_on_error: false,
            },
            ..CallOptions::default()
        });
    let t0 = world.net.now();
    let done = stub_call_blocking(&mut world, &client, &mut stub, "work", b"", 15 * SECOND)
        .expect("call completes");
    assert_eq!(done.status, Status::Ok, "detail: {}", done.detail);
    assert_eq!(done.payload, b"served");
    // The whole dance — dropped reply answered, backoff, failover — must
    // be an immediate-failover path, nowhere near the 10 s budget.
    assert!(
        world.net.now() - t0 < SECOND,
        "dropped reply must fail fast, not wait out the deadline"
    );
    assert!(stub.stats.failovers >= 1, "stats: {}", stub.stats.summary());
    assert_eq!(nodes[1].borrow().rpc.replies_dropped, 1);
}

/// While any target signals overload, speculative hedges are suppressed
/// — duplicates are pure amplification against a saturated server.
#[test]
fn hedges_suppressed_under_overload_signal() {
    let (mut world, client, server) = table1_world(NetScenario::SameRegionLan, 37);
    let server_peer = server.borrow().peer_id();
    server.borrow_mut().register_service(
        Service::new("jam")
            .with_admission(AdmissionPolicy::rate(0.0, 0.0).with_retry_after(300 * MILLI))
            .unary("work", |_node, _net, _ctx, _payload| Outcome::reply(&b"x"[..])),
    );

    let mut stub = Stub::new("jam", vec![server_peer]).with_options(CallOptions {
        deadline: 2 * SECOND,
        retry: RetryPolicy {
            max_attempts: 3,
            base_backoff: 10 * MILLI,
            max_backoff: 200 * MILLI,
            jitter: 0.0,
            retry_on_error: false,
        },
        hedge: HedgePolicy::on(),
        ..CallOptions::default()
    });
    let done = stub_call_blocking(&mut world, &client, &mut stub, "work", b"", 10 * SECOND)
        .expect("call completes");
    assert_eq!(done.status, Status::Overloaded);
    assert_eq!(
        stub.stats.hedges, 0,
        "no speculative duplicates against a shedding target: {}",
        stub.stats.summary()
    );
    assert!(
        stub.stats.hedges_suppressed >= 1,
        "suppression must be counted: {}",
        stub.stats.summary()
    );
    assert!(stub.stats.overloaded >= 1);
}

/// The metastable-overload scenario end to end: a mixed retrying+hedging
/// fleet drives the replicated service at 10× capacity; admission +
/// pushback must hold goodput, shed almost everything before decode, and
/// recover without operator action. Release-only (drives ~50k calls).
#[test]
#[cfg_attr(debug_assertions, ignore = "slow in debug: run with --release")]
fn overload_scenario_sheds_cheaply_and_recovers() {
    let out = overload_scenario(&OverloadConfig::default());
    let fmt = |r: &lattica::scenarios::OverloadRow| {
        format!(
            "{}: offered {:.0}/s goodput {:.0}/s ok {} rejected {} shed_pre {} shed_q {}",
            r.phase, r.offered_qps, r.goodput_qps, r.ok, r.rejected, r.shed_predecode, r.shed_queue
        )
    };
    let detail: Vec<String> = out.rows.iter().map(fmt).collect();
    let surge = &out.rows[1];
    let recover = &out.rows[2];

    assert!(
        out.capacity_qps >= 0.5 * out.nominal_capacity_qps,
        "measured capacity {:.0}/s implausibly far under nominal {:.0}/s\n{detail:?}",
        out.capacity_qps,
        out.nominal_capacity_qps
    );
    assert!(
        surge.goodput_qps >= 0.8 * out.capacity_qps,
        "goodput under 10x surge {:.0}/s must hold >=80% of capacity {:.0}/s\n{detail:?}",
        surge.goodput_qps,
        out.capacity_qps
    );
    let total_shed = out.shed_predecode + out.shed_queue;
    assert!(
        total_shed > 0 && out.shed_predecode as f64 >= 0.9 * total_shed as f64,
        "at least 90% of sheds must be pre-decode: pre {} / total {total_shed}\n{detail:?}",
        out.shed_predecode
    );
    assert!(
        recover.goodput_qps >= 0.8 * recover.offered_qps,
        "goodput must recover without operator action: {:.0}/s of {:.0}/s offered\n{detail:?}",
        recover.goodput_qps,
        recover.offered_qps
    );
    // The pushback machinery actually engaged.
    assert!(out.stub.overloaded > 0, "stub: {}", out.stub.summary());
    assert!(
        out.stub.hedges_suppressed > 0,
        "hedges must be suppressed during the surge: {}",
        out.stub.summary()
    );
}
