//! Property suite for the CRDT merge laws: seeded random op sequences
//! asserting commutativity, associativity and idempotence of `merge`, and
//! digest agreement, for all four types (`GCounter`, `PnCounter`,
//! `LwwRegister`, `OrSet`) — plus the store-level laws over mixed-type
//! states. Failures shrink the op count and panic with a replay line,
//! like `dht_churn`'s CRDT convergence test.

use lattica::crdt::{Crdt, CrdtStore, GCounter, LwwRegister, OrSet, PnCounter};
use lattica::util::Rng;
use lattica::wire::Message;

const REPLICAS: u64 = 4;

/// Apply `ops` seeded random operations to three states of one type,
/// building divergent-but-mergeable replicas A, B, C.
fn gen3<T: Clone, F: FnMut(&mut T, &mut Rng)>(
    mut init: impl FnMut() -> T,
    mut op: F,
    seed: u64,
    ops: usize,
) -> (T, T, T) {
    let mut rng = Rng::new(seed);
    let mut states = [init(), init(), init()];
    for _ in 0..ops {
        let i = rng.gen_index(3);
        op(&mut states[i], &mut rng);
    }
    let [a, b, c] = states;
    (a, b, c)
}

fn merged<T: Crdt>(x: &T, y: &T) -> T {
    let mut m = x.clone();
    m.merge(y);
    m
}

/// Check the three merge laws for one type; values are compared through
/// `wrap` (a canonical encoding) so structural equality is byte equality.
fn check_laws<T: Crdt, W: Fn(&T) -> Vec<u8>>(
    a: &T,
    b: &T,
    c: &T,
    wrap: W,
    label: &str,
) -> Result<(), String> {
    // Commutativity: a ∪ b == b ∪ a.
    if wrap(&merged(a, b)) != wrap(&merged(b, a)) {
        return Err(format!("{label}: merge not commutative"));
    }
    // Associativity: (a ∪ b) ∪ c == a ∪ (b ∪ c).
    let left = merged(&merged(a, b), c);
    let right = merged(a, &merged(b, c));
    if wrap(&left) != wrap(&right) {
        return Err(format!("{label}: merge not associative"));
    }
    // Idempotence: a ∪ a == a and (a ∪ b) ∪ b == a ∪ b.
    if wrap(&merged(a, a)) != wrap(a) {
        return Err(format!("{label}: self-merge not idempotent"));
    }
    let ab = merged(a, b);
    if wrap(&merged(&ab, b)) != wrap(&ab) {
        return Err(format!("{label}: re-merge not idempotent"));
    }
    Ok(())
}

/// One seeded case over all four types. Returns a failure description so
/// the caller can shrink and print a replay.
fn crdt_props_case(seed: u64, ops: usize) -> Result<(), String> {
    // GCounter.
    let (a, b, c) = gen3(
        GCounter::new,
        |g, rng| g.increment(rng.gen_range(REPLICAS), 1 + rng.gen_range(9)),
        seed,
        ops,
    );
    check_laws(&a, &b, &c, |g| g.encode(), "gcounter")?;
    let m = merged(&merged(&a, &b), &c);
    let total = a.value() + b.value() + c.value();
    if m.value() > total {
        return Err(format!(
            "gcounter merge invented increments: {} > {total}",
            m.value()
        ));
    }

    // PnCounter.
    let (a, b, c) = gen3(
        PnCounter::new,
        |p, rng| {
            let r = rng.gen_range(REPLICAS);
            if rng.gen_bool(0.5) {
                p.increment(r, 1 + rng.gen_range(9));
            } else {
                p.decrement(r, 1 + rng.gen_range(4));
            }
        },
        seed ^ 0xA1,
        ops,
    );
    check_laws(&a, &b, &c, |p| p.encode(), "pncounter")?;

    // LwwRegister — random timestamps with deliberate ties so the
    // (ts, replica) tiebreak is exercised.
    let (a, b, c) = gen3(
        LwwRegister::new,
        |l, rng| {
            let ts = rng.gen_range(ops as u64 / 2 + 1);
            let r = rng.gen_range(REPLICAS);
            l.set(format!("v{}", rng.gen_range(1000)).into_bytes(), ts, r);
        },
        seed ^ 0xB2,
        ops,
    );
    check_laws(&a, &b, &c, |l| l.encode(), "lww")?;

    // OrSet — adds and removes over a small element universe.
    let (a, b, c) = gen3(
        OrSet::new,
        |s, rng| {
            let e = format!("e{}", rng.gen_range(12));
            if rng.gen_bool(0.7) {
                s.add(rng.gen_range(REPLICAS), e.as_bytes());
            } else {
                s.remove(e.as_bytes());
            }
        },
        seed ^ 0xC3,
        ops,
    );
    check_laws(&a, &b, &c, |s| s.encode(), "orset")?;

    // Store-level: mixed-type states must satisfy the same laws, and the
    // digest must agree exactly when the encodings agree.
    let mk_store = |seed: u64| {
        let mut rng = Rng::new(seed);
        let mut st = CrdtStore::new();
        for _ in 0..ops {
            match rng.gen_index(4) {
                0 => st
                    .gcounter("steps")
                    .increment(rng.gen_range(REPLICAS), 1 + rng.gen_range(5)),
                1 => {
                    let r = rng.gen_range(REPLICAS);
                    if rng.gen_bool(0.5) {
                        st.pncounter("credits").increment(r, 1 + rng.gen_range(5));
                    } else {
                        st.pncounter("credits").decrement(r, 1 + rng.gen_range(2));
                    }
                }
                2 => {
                    let ts = rng.gen_range(50);
                    let r = rng.gen_range(REPLICAS);
                    st.lww("leader").set(vec![ts as u8], ts, r);
                }
                _ => {
                    let e = format!("m{}", rng.gen_range(8));
                    st.orset("members").add(rng.gen_range(REPLICAS), e.as_bytes());
                }
            }
        }
        st
    };
    let (sa, sb, sc) = (mk_store(seed ^ 0xD4), mk_store(seed ^ 0xE5), mk_store(seed ^ 0xF6));
    let smerge = |x: &CrdtStore, y: &CrdtStore| {
        let mut m = x.clone();
        m.merge(y).expect("same-typed keys");
        m
    };
    let ab_c = smerge(&smerge(&sa, &sb), &sc);
    let a_bc = smerge(&sa, &smerge(&sb, &sc));
    if ab_c.encode() != a_bc.encode() {
        return Err("store: merge not associative".into());
    }
    if smerge(&sa, &sb).encode() != smerge(&sb, &sa).encode() {
        return Err("store: merge not commutative".into());
    }
    if smerge(&ab_c, &ab_c).encode() != ab_c.encode() {
        return Err("store: merge not idempotent".into());
    }
    // Digest agreement both ways: equal states ⇒ equal digests, and a
    // state change ⇒ digest change.
    if ab_c.digest() != a_bc.digest() {
        return Err("store: digests diverge on equal states".into());
    }
    let mut bumped = ab_c.clone();
    bumped.gcounter("steps").increment(0, 1);
    if bumped.digest() == ab_c.digest() {
        return Err("store: digest blind to a state change".into());
    }
    Ok(())
}

#[test]
fn merge_laws_hold_across_seeds() {
    // Many seeded interleavings; on failure, shrink the op count for the
    // failing seed so the panic carries a minimal replay
    // (`crdt_props_case(seed, ops)`).
    for seed in 1..=40u64 {
        let ops = 200;
        if let Err(err) = crdt_props_case(seed, ops) {
            let mut min_ops = ops;
            while min_ops > 1 && crdt_props_case(seed, min_ops - 1).is_err() {
                min_ops -= 1;
            }
            panic!("CRDT law violation: {err}\n  replay: crdt_props_case({seed}, {min_ops})");
        }
    }
}

#[test]
fn digest_agreement_for_each_type() {
    // Converged replicas must agree byte-for-byte per type, through the
    // store digest.
    for seed in [7u64, 21, 33] {
        let mut a = CrdtStore::new();
        let mut b = CrdtStore::new();
        let mut rng = Rng::new(seed);
        for _ in 0..150 {
            let (st, r) = if rng.gen_bool(0.5) { (&mut a, 0u64) } else { (&mut b, 1u64) };
            match rng.gen_index(4) {
                0 => st.gcounter("g").increment(r, 1 + rng.gen_range(3)),
                1 => st.pncounter("p").decrement(r, 1 + rng.gen_range(3)),
                2 => st.lww("l").set(vec![rng.gen_range(250) as u8], rng.gen_range(40), r),
                _ => st.orset("o").add(r, format!("x{}", rng.gen_range(6)).as_bytes()),
            }
        }
        let a0 = a.clone();
        a.merge(&b).unwrap();
        b.merge(&a0).unwrap();
        assert_eq!(a.digest(), b.digest(), "seed {seed}: digests diverged");
        assert_eq!(a.encode(), b.encode(), "seed {seed}: not byte-identical");
    }
}
