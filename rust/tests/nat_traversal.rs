//! NAT traversal acceptance suite: the measured punch matrix must track
//! its calibration bands, a mixed-NAT mesh must reach near-full pairwise
//! connectivity through autoscaled relays, and killing a relay mid-stream
//! must not drop the logical connections riding its circuits.
//!
//! The quick arms run in debug builds; the strict mesh arm is
//! release-gated (CI runs it) like the other heavy scenarios.

use lattica::netsim::nat::{measure_punch_matrix, punch_success_band, NatType};
use lattica::scenarios::{nat_mesh, NatMeshConfig};

/// The per-pair punch success rates out of the realistic lab harness must
/// land inside the configured calibration bands (Trautwein et al. shape:
/// cone-cone easy, cone-symmetric hard, symmetric-symmetric mostly lost),
/// within sampling slack.
#[test]
fn punch_matrix_tracks_calibration_bands() {
    let trials = 80u32;
    let slack = 0.25 / (trials as f64).sqrt() * 3.0; // ~3σ for a proportion
    for (a, b, rate) in measure_punch_matrix(trials, 16, 11) {
        let (lo, hi) = punch_success_band(a, b);
        assert!(
            rate >= lo - slack && rate <= hi + slack,
            "{}|{} measured {:.3} outside band [{lo}, {hi}] (slack {slack:.3})",
            a.label(),
            b.label(),
            rate
        );
    }
}

/// Relative structure regression: the matrix must keep its ordering even
/// if the absolute calibration shifts — symmetric pairs are the hard
/// wall, cone pairs are easy, and the port spray keeps cone↔symmetric
/// usable.
#[test]
fn punch_matrix_ordering_is_stable() {
    use NatType::*;
    let m = measure_punch_matrix(80, 16, 23);
    let rate = |x: NatType, y: NatType| {
        m.iter()
            .find(|(a, b, _)| (*a == x && *b == y) || (*a == y && *b == x))
            .map(|(_, _, r)| *r)
            .unwrap()
    };
    assert!(rate(FullCone, FullCone) > rate(PortRestrictedCone, Symmetric));
    assert!(rate(PortRestrictedCone, Symmetric) > rate(Symmetric, Symmetric));
    assert!(
        rate(Symmetric, Symmetric) < 0.5,
        "symmetric|symmetric must stay a hard wall"
    );
}

/// Small mixed-NAT mesh: AutoNAT classification, relay ads, load-aware
/// reservations, and circuit dialing must yield near-full pairwise
/// connectivity (relayed paths count).
#[test]
fn mixed_nat_mesh_connects() {
    let mut cfg = NatMeshConfig::quick(3);
    cfg.nodes = 18;
    cfg.pair_samples = 15;
    let out = nat_mesh(&cfg);
    assert!(
        out.reservation_coverage >= 0.8,
        "only {:.0}% of NATted nodes hold a relay reservation after settle",
        out.reservation_coverage * 100.0
    );
    assert!(
        out.connectivity >= 0.9,
        "mesh connectivity {:.3} ({} of {} sampled pairs)",
        out.connectivity,
        out.connected,
        out.attempted
    );
}

/// The acceptance-bar mesh: ≥95 % pairwise connectivity at the quick-arm
/// scale, with every relay inside its egress budget. Heavy — release
/// builds only (CI runs it; the 1k-node arm lives in the bench).
#[test]
#[cfg_attr(debug_assertions, ignore = "release-mode scenario; run via CI or --include-ignored")]
fn mixed_nat_mesh_meets_acceptance_bar() {
    let mut cfg = NatMeshConfig::quick(7);
    cfg.relay_egress_bps = 50_000_000;
    let out = nat_mesh(&cfg);
    assert!(
        out.connectivity >= 0.95,
        "mesh connectivity {:.3} below the 95% acceptance bar ({} of {})",
        out.connectivity,
        out.connected,
        out.attempted
    );
    for r in &out.relay_rows {
        assert!(
            r.egress_bps_avg <= 50_000_000,
            "relay {} exceeded its egress budget: {} B/s",
            r.label,
            r.egress_bps_avg
        );
    }
}

/// Kill the relay under an active circuit: the initiator must re-home the
/// inner connection to a backup relay without surfacing a disconnect, and
/// RPCs must keep completing afterwards.
#[test]
#[cfg_attr(debug_assertions, ignore = "release-mode scenario; run via CI or --include-ignored")]
fn relay_kill_failover_keeps_logical_connection() {
    let mut cfg = NatMeshConfig::quick(5);
    cfg.nodes = 16;
    cfg.pair_samples = 0; // the kill arm picks its own pair
    cfg.relay_kill = true;
    let out = nat_mesh(&cfg);
    let f = out
        .failover
        .expect("no NATted pair with two shared reservations found");
    assert!(f.recovered, "inner connection did not re-home to a backup relay");
    assert!(
        !f.peer_disconnected_seen,
        "failover surfaced a PeerDisconnected for the logical connection"
    );
    assert!(f.call_after_kill_ok, "RPC after the relay kill did not complete");
    assert!(f.failovers_completed >= 1);
}
