//! Churn-resilience hardening suite: routing-table invariants, churn-plan
//! determinism, query failover around dead peers, TTL/republish behaviour,
//! CRDT convergence under randomized churn, and the 200-node
//! `bootstrap_mesh` churn scenario from the acceptance criteria.
//!
//! Everything here is seeded and deterministic; the heavyweight 200-node
//! scenario is ignored under debug builds and runs in CI's release pass.

use lattica::crdt::CrdtStore;
use lattica::identity::Keypair;
use lattica::netsim::topology::LinkProfile;
use lattica::netsim::{ChurnAction, ChurnConfig, ChurnEvent, ChurnPlan, SECOND};
use lattica::node::{run_until, LatticaNode, NodeEvent};
use lattica::protocols::kad::{
    xor_distance, InsertOutcome, KadEvent, PeerEntry, RoutingTable, K,
};
use lattica::protocols::Ctx;
use lattica::scenarios::{bootstrap_mesh, churn_scenario};
use lattica::util::Rng;
use lattica::wire::Message;

fn entry(seed: u64) -> PeerEntry {
    PeerEntry {
        id: Keypair::from_seed(seed).peer_id(),
        host: seed as u32,
        port: 4001,
    }
}

// ---------------------------------------------------------------------------
// Routing-table invariants (deterministic seeded cases)
// ---------------------------------------------------------------------------

#[test]
fn invariant_bucket_size_never_exceeds_k() {
    for seed in [1u64, 2, 3] {
        let local = Keypair::from_seed(seed * 1000).peer_id();
        let mut rt = RoutingTable::new(local);
        let mut rng = Rng::new(seed);
        for i in 0..500u64 {
            let _ = rt.insert(entry(rng.gen_range(10_000)), i);
            // Interleave churn-ish operations.
            if rng.gen_bool(0.2) {
                let victim = entry(rng.gen_range(10_000)).id;
                rt.mark_failed(&victim);
            }
            if rng.gen_bool(0.1) {
                rt.mark_alive(&entry(rng.gen_range(10_000)).id, i);
            }
        }
        for b in 0..256 {
            assert!(rt.bucket_len(b) <= K, "seed {seed}: bucket {b} exceeds K");
        }
    }
}

#[test]
fn invariant_local_peer_never_inserted() {
    let local = Keypair::from_seed(42).peer_id();
    let mut rt = RoutingTable::new(local);
    for i in 1..=100u64 {
        let _ = rt.insert(entry(i), i);
    }
    assert_eq!(
        rt.insert(PeerEntry { id: local, host: 1, port: 1 }, 999),
        InsertOutcome::Ignored
    );
    assert!(rt.iter().all(|e| e.id != local));
}

#[test]
fn invariant_closest_sorted_by_xor_distance() {
    let local = Keypair::from_seed(0).peer_id();
    let mut rt = RoutingTable::new(local);
    for i in 1..=120u64 {
        let _ = rt.insert(entry(i), i);
    }
    for key_seed in [5u64, 77, 901, 4096] {
        let key = *Keypair::from_seed(key_seed).peer_id().as_bytes();
        let closest = rt.closest(&key, K);
        for w in closest.windows(2) {
            assert!(
                xor_distance(w[0].id.as_bytes(), &key) <= xor_distance(w[1].id.as_bytes(), &key),
                "closest() must be sorted by XOR distance"
            );
        }
        // They must be the true closest over the whole table.
        let mut all: Vec<PeerEntry> = rt.iter().cloned().collect();
        all.sort_by_key(|e| xor_distance(e.id.as_bytes(), &key));
        let want: Vec<_> = all.iter().take(closest.len()).map(|e| e.id).collect();
        let got: Vec<_> = closest.iter().map(|e| e.id).collect();
        assert_eq!(got, want);
    }
}

#[test]
fn invariant_eviction_prefers_dead_over_fresh() {
    let local = Keypair::from_seed(0).peer_id();
    // Collect seeds that land in one shared bucket (255 holds half of all
    // random ids, so it overfills quickly).
    let mut seeds_in_bucket: Vec<u64> = Vec::new();
    for s in 1..=600u64 {
        let id = Keypair::from_seed(s).peer_id();
        if local.bucket_index(&id) == Some(255) {
            seeds_in_bucket.push(s);
        }
    }
    assert!(seeds_in_bucket.len() > K + 1);
    let mut rt = RoutingTable::new(local);
    for (i, s) in seeds_in_bucket.iter().take(K).enumerate() {
        assert_eq!(rt.insert(entry(*s), i as u64), InsertOutcome::Added);
    }
    // All live: the table refuses to evict silently.
    let newcomer = entry(seeds_in_bucket[K]);
    assert!(matches!(
        rt.insert(newcomer.clone(), 50),
        InsertOutcome::Full { .. }
    ));
    // One entry goes dead (a single failed request — not yet removed).
    let dead = entry(seeds_in_bucket[7]).id;
    assert!(!rt.mark_failed(&dead));
    assert!(rt.iter().any(|e| e.id == dead));
    // Now the newcomer displaces the dead entry, not a fresh one.
    assert_eq!(rt.insert(newcomer.clone(), 51), InsertOutcome::Added);
    assert!(rt.iter().all(|e| e.id != dead), "dead peer must go first");
    assert!(rt.iter().any(|e| e.id == newcomer.id));
    assert_eq!(rt.bucket_len(255), K);
}

// ---------------------------------------------------------------------------
// Churn-plan determinism contract
// ---------------------------------------------------------------------------

#[test]
fn churn_plan_same_seed_same_trace() {
    let cfg = ChurnConfig {
        nodes: 60,
        protected: 3,
        start: 10 * SECOND,
        end: 100 * SECOND,
        session_half_life: 60 * SECOND,
        downtime_mean: 10 * SECOND,
        crash_fraction: 0.5,
    };
    let a = ChurnPlan::poisson(&cfg, 12345);
    let b = ChurnPlan::poisson(&cfg, 12345);
    assert_eq!(a.events(), b.events(), "same seed must give the same trace");
    assert_eq!(a.trace_digest(), b.trace_digest());
    assert_ne!(
        a.trace_digest(),
        ChurnPlan::poisson(&cfg, 12346).trace_digest(),
        "different seeds must diverge"
    );
    // Protected nodes never appear; both leave kinds occur.
    assert!(a.events().iter().all(|e| e.node >= 3 && e.node < 60));
    assert!(a.events().iter().any(|e| e.action == ChurnAction::Crash));
    assert!(a.events().iter().any(|e| e.action == ChurnAction::Leave));
    assert!(a.events().iter().any(|e| e.action == ChurnAction::Join));
}

// ---------------------------------------------------------------------------
// Query failover around dead peers (the on_peer_unreachable fix)
// ---------------------------------------------------------------------------

#[test]
fn lookup_fails_over_instead_of_stalling_on_crashed_peer() {
    let (mut world, nodes) = bootstrap_mesh(6, 501, LinkProfile::DATACENTER);
    // Crash node 5 without a goodbye: peers still have it in their tables.
    let crashed_peer = nodes[5].borrow().peer_id();
    let target = *crashed_peer.as_bytes();
    {
        let eid = nodes[5].borrow().endpoint_id();
        nodes[5].borrow_mut().shutdown(&mut world.net, false);
        world.remove_endpoint(eid);
    }
    // A lookup towards the crashed node's key must still complete: the
    // request to the dead peer times out (or its dial fails) and the query
    // re-issues to the next-closest candidates.
    let t0 = world.net.now();
    let qid = {
        let mut nd = nodes[1].borrow_mut();
        let LatticaNode { swarm, kad, .. } = &mut *nd;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        kad.find_node(&mut ctx, target)
    };
    let mut finished = false;
    run_until(&mut world, 12 * SECOND, || {
        if !finished {
            let mut nd = nodes[1].borrow_mut();
            for e in nd.drain_events() {
                if let NodeEvent::Kad(KadEvent::QueryFinished { query_id, .. }) = e {
                    if query_id == qid {
                        finished = true;
                    }
                }
            }
        }
        finished
    });
    assert!(finished, "query stalled on the crashed peer");
    // Well under the no-failover worst case (handshake timeout ≫ this).
    let elapsed = world.net.now() - t0;
    assert!(
        elapsed < 9 * SECOND,
        "failover took too long: {} ns",
        elapsed
    );
}

#[test]
fn clean_leave_prunes_peer_tables() {
    let (mut world, nodes) = bootstrap_mesh(6, 503, LinkProfile::DATACENTER);
    let leaver = nodes[3].borrow().peer_id();
    assert!(nodes[0].borrow().kad.table.iter().any(|e| e.id == leaver));
    {
        let eid = nodes[3].borrow().endpoint_id();
        nodes[3].borrow_mut().shutdown(&mut world.net, true);
        world.remove_endpoint(eid);
    }
    // The goodbye reaches connected peers, which drop the leaver.
    run_until(&mut world, 5 * SECOND, || {
        nodes[0].borrow().kad.table.iter().all(|e| e.id != leaver)
    });
    assert!(
        nodes[0].borrow().kad.table.iter().all(|e| e.id != leaver),
        "bootstrap node must drop a cleanly-leaving peer"
    );
}

// ---------------------------------------------------------------------------
// Provider TTL expiry + republish keep-alive
// ---------------------------------------------------------------------------

#[test]
fn provider_records_expire_without_republish_and_survive_with_it() {
    let (mut world, nodes) = bootstrap_mesh(8, 505, LinkProfile::DATACENTER);
    // Tight TTL, republish effectively off.
    for n in &nodes {
        let mut nd = n.borrow_mut();
        nd.kad.provider_ttl = 2 * SECOND;
        nd.kad.set_republish_interval(1000 * SECOND);
    }
    let key = *Keypair::from_seed(4242).peer_id().as_bytes();
    {
        let mut nd = nodes[1].borrow_mut();
        let LatticaNode { swarm, kad, .. } = &mut *nd;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        kad.provide(&mut ctx, key);
    }
    world.run_for(SECOND);
    let lookup = |world: &mut lattica::netsim::World,
                  nodes: &[lattica::scenarios::Node],
                  src: usize| {
        let qid = {
            let mut nd = nodes[src].borrow_mut();
            let LatticaNode { swarm, kad, .. } = &mut *nd;
            let mut ctx = Ctx::new(swarm, &mut world.net);
            kad.get_providers(&mut ctx, key)
        };
        let mut found = None;
        run_until(world, 10 * SECOND, || {
            if found.is_none() {
                let mut nd = nodes[src].borrow_mut();
                for e in nd.drain_events() {
                    if let NodeEvent::Kad(KadEvent::QueryFinished {
                        query_id, providers, ..
                    }) = e
                    {
                        if query_id == qid {
                            found = Some(!providers.is_empty());
                        }
                    }
                }
            }
            found.is_some()
        });
        found.unwrap_or(false)
    };
    assert!(lookup(&mut world, &nodes, 5), "fresh record must resolve");
    // TTL passes with republish disabled: the record disappears everywhere.
    world.run_for(4 * SECOND);
    assert!(
        !lookup(&mut world, &nodes, 6),
        "expired record must not resolve"
    );
    // Re-enable republish: the provider re-announces and stays resolvable
    // across several TTL windows.
    nodes[1].borrow_mut().kad.set_republish_interval(SECOND);
    world.run_for(3 * SECOND);
    assert!(
        lookup(&mut world, &nodes, 7),
        "republish must keep the record alive"
    );
    world.run_for(6 * SECOND);
    assert!(
        lookup(&mut world, &nodes, 2),
        "record must survive multiple TTL windows under republish"
    );
}

// ---------------------------------------------------------------------------
// CRDT convergence under randomized churn (partition + rejoin)
// ---------------------------------------------------------------------------

/// One randomized interleaving: `replicas` stores apply `ops` seeded
/// operations with a partition across the first half of the run, partial
/// syncs inside partitions, then full anti-entropy. Convergence must be
/// byte-identical (equal digests AND equal encodings). Returns the failure
/// description if the case fails.
fn crdt_churn_case(seed: u64, replicas: usize, ops: usize) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let mut stores: Vec<CrdtStore> = (0..replicas).map(|_| CrdtStore::new()).collect();
    let half = replicas / 2;
    for i in 0..ops {
        let r = rng.gen_index(replicas);
        match rng.gen_index(5) {
            0 => stores[r].gcounter("train/steps").increment(r as u64, 1 + rng.gen_range(4)),
            1 => {
                if rng.gen_bool(0.5) {
                    stores[r].pncounter("credits").increment(r as u64, rng.gen_range(9) + 1);
                } else {
                    stores[r].pncounter("credits").decrement(r as u64, rng.gen_range(3) + 1);
                }
            }
            2 => {
                let member = format!("peer-{}", rng.gen_index(replicas * 3));
                stores[r].orset("members").add(r as u64, member.as_bytes());
            }
            3 => {
                let member = format!("peer-{}", rng.gen_index(replicas * 3));
                stores[r].orset("members").remove(member.as_bytes());
            }
            _ => {
                let v = format!("ckpt-{i}");
                stores[r].lww("model/latest").set(v.into_bytes(), i as u64, r as u64);
            }
        }
        // Random partial sync — during the partition phase only within the
        // same side; afterwards (rejoin) anywhere.
        if rng.gen_bool(0.3) {
            let a = rng.gen_index(replicas);
            let b = rng.gen_index(replicas);
            let partitioned = i < ops / 2;
            if a != b && (!partitioned || (a < half) == (b < half)) {
                let other = stores[b].clone();
                stores[a].merge(&other).map_err(|e| format!("merge failed: {e}"))?;
            }
        }
    }
    // Heal: two rounds of full-mesh anti-entropy.
    for _ in 0..2 {
        for a in 0..replicas {
            for b in 0..replicas {
                if a != b {
                    let other = stores[b].clone();
                    stores[a].merge(&other).map_err(|e| format!("merge failed: {e}"))?;
                }
            }
        }
    }
    let d0 = stores[0].digest();
    let e0 = stores[0].encode();
    for (i, s) in stores.iter().enumerate().skip(1) {
        if s.digest() != d0 {
            return Err(format!("replica {i} digest diverged"));
        }
        if s.encode() != e0 {
            return Err(format!("replica {i} encoding diverged (not byte-identical)"));
        }
    }
    Ok(())
}

#[test]
fn crdt_converges_byte_identically_under_churn() {
    // Many seeded interleavings across 3..5 replicas. On failure, shrink
    // the op count for the failing seed so the panic message carries a
    // minimal replay (`crdt_churn_case(seed, replicas, ops)`).
    for seed in 1..=25u64 {
        let replicas = 3 + (seed as usize % 3);
        let ops = 300;
        if let Err(err) = crdt_churn_case(seed, replicas, ops) {
            let mut min_ops = ops;
            while min_ops > 1 && crdt_churn_case(seed, replicas, min_ops - 1).is_err() {
                min_ops -= 1;
            }
            panic!(
                "CRDT divergence: {err}\n  replay: crdt_churn_case({seed}, {replicas}, {min_ops})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// The churn scenario itself
// ---------------------------------------------------------------------------

/// Debug-friendly scenario: 30 nodes, aggressive 20 s half-life.
#[test]
fn churn_scenario_small_mesh_keeps_lookups_alive() {
    let o = churn_scenario(30, 20, 40, 77);
    assert!(o.leaves + o.crashes > 0, "plan must actually churn nodes");
    assert!(o.joins > 0, "nodes must rejoin");
    assert!(
        o.stats.success_rate() >= 0.90,
        "small-mesh churn success too low: {:.3} ({})",
        o.stats.success_rate(),
        o.stats.clone().summary()
    );
}

/// The acceptance scenario: 200 nodes, 60 s median session half-life.
/// Heavy — ignored in debug builds, exercised by CI's release run.
#[test]
#[cfg_attr(debug_assertions, ignore = "release-mode scenario; run via CI or --include-ignored")]
fn churn_scenario_200_nodes_95pct_success() {
    // Control arm: churn disabled, the same harness — lookups must be
    // essentially lossless and early-exit quickly (no hop regression).
    let control = churn_scenario(200, 0, 60, 90001);
    assert!(
        control.stats.success_rate() >= 0.99,
        "no-churn control must succeed: {:.3}",
        control.stats.success_rate()
    );
    assert!(
        control.stats.mean_hops() <= 12.0,
        "no-churn hop count regressed: {:.1}",
        control.stats.mean_hops()
    );
    // Churn arm: 60 s median session half-life.
    let o = churn_scenario(200, 60, 90, 90001);
    assert!(o.leaves + o.crashes >= 20, "expected substantial churn");
    assert!(
        o.stats.success_rate() >= 0.95,
        "churned success rate below the 95% bar: {:.3} ({:?})",
        o.stats.success_rate(),
        o.kad
    );
}

// ---------------------------------------------------------------------------
// Determinism of the full simulated scenario
// ---------------------------------------------------------------------------

#[test]
fn churn_application_is_deterministic() {
    // The ChurnPlan contract (same seed ⇒ same trace) extends through plan
    // application: churn counts and the lookup schedule are pure functions
    // of the seeds. (Packet-level traces additionally depend on process-
    // local hash ordering in the swarm, so they are not asserted here.)
    let a = churn_scenario(20, 15, 20, 31337);
    let b = churn_scenario(20, 15, 20, 31337);
    assert_eq!(a.stats.attempted, b.stats.attempted);
    assert_eq!(a.joins, b.joins);
    assert_eq!(a.leaves, b.leaves);
    assert_eq!(a.crashes, b.crashes);
    let e = ChurnEvent { at: 5, node: 2, action: ChurnAction::Crash };
    assert_eq!(e, ChurnEvent { at: 5, node: 2, action: ChurnAction::Crash });
}

// ---------------------------------------------------------------------------
// Timer-wheel equivalence: the hierarchical wheel must reproduce the
// reference heap's dispatch stream byte-for-byte
// ---------------------------------------------------------------------------

mod wheel_equivalence {
    //! The full node stack's packet trace depends on process-local hash
    //! ordering (see `churn_application_is_deterministic` above), so the
    //! byte-identical comparison runs a netsim-level scenario whose event
    //! stream is a pure function of the seed: 50 `Chatter` endpoints with
    //! jittered timers spanning every wheel level, plus a Poisson churn
    //! plan that removes and respawns endpoints mid-flight. Identical
    //! `World::trace_digest` under `QueueKind::Heap` and `QueueKind::Wheel`
    //! means identical delivery order, timestamps and payloads.

    use lattica::multiaddr::SimAddr;
    use lattica::netsim::topology::LinkProfile;
    use lattica::netsim::{
        ChurnAction, ChurnConfig, ChurnPlan, Endpoint, EndpointId, Net, QueueKind,
        TopologyBuilder, World, MICRO, MILLI, SECOND,
    };
    use lattica::util::Rng;
    use std::cell::RefCell;
    use std::rc::Rc;

    const CHAT_PORT: u16 = 7000;
    const TICK: u64 = 1;

    /// Deterministic traffic source: every tick, send a random-length
    /// datagram to a seeded-random peer and re-arm with a jittered delay;
    /// echo every other datagram received. No hash-ordered state anywhere.
    struct Chatter {
        id: EndpointId,
        addr: SimAddr,
        peers: Rc<Vec<SimAddr>>,
        rng: Rng,
        received: u64,
    }

    impl Chatter {
        fn spawn(
            world: &mut World,
            addr: SimAddr,
            peers: Rc<Vec<SimAddr>>,
            seed: u64,
        ) -> EndpointId {
            let ep = Rc::new(RefCell::new(Chatter {
                id: 0,
                addr,
                peers,
                rng: Rng::new(seed),
                received: 0,
            }));
            let id = world.add_endpoint(ep.clone());
            ep.borrow_mut().id = id;
            world.net.bind(id, addr).expect("port free after unbind");
            let first = ep.borrow_mut().next_delay();
            world.net.set_timer(id, first, TICK);
            id
        }

        /// Delays drawn from five bands — sub-slot microseconds (same-tick
        /// coalescing) through multi-second horizons (upper wheel levels,
        /// cascade on expiry).
        fn next_delay(&mut self) -> u64 {
            let j = self.rng.next_u64();
            match j % 5 {
                0 => 100 * MICRO + (j >> 3) % (900 * MICRO),
                1 => 2 * MILLI + (j >> 3) % (60 * MILLI),
                2 => 80 * MILLI + (j >> 3) % (400 * MILLI),
                3 => 700 * MILLI + (j >> 3) % (2 * SECOND),
                _ => 3 * SECOND + (j >> 3) % (5 * SECOND),
            }
        }
    }

    impl Endpoint for Chatter {
        fn on_datagram(&mut self, net: &mut Net, from: SimAddr, to: SimAddr, _payload: Vec<u8>) {
            self.received += 1;
            if self.received % 2 == 0 {
                net.send(to, from, vec![0xEC; 9]);
            }
        }

        fn on_timer(&mut self, net: &mut Net, token: u64) {
            debug_assert_eq!(token, TICK);
            let peer = self.peers[self.rng.gen_index(self.peers.len())];
            if peer != self.addr {
                let len = 16 + (self.rng.next_u64() % 180) as usize;
                let mut payload = vec![0u8; len];
                self.rng.fill_bytes(&mut payload);
                net.send(self.addr, peer, payload);
            }
            let d = self.next_delay();
            net.set_timer(self.id, d, TICK);
        }
    }

    /// The seeded 50-node churn scenario on the given queue implementation.
    /// Returns `(trace digest, events processed, stale drops)`.
    fn chatter_trace(kind: QueueKind, seed: u64) -> (u64, u64, u64) {
        const N: usize = 50;
        let mut t = TopologyBuilder::paper_regions();
        t.set_queue_kind(kind);
        let hosts: Vec<u32> =
            (0..N).map(|i| t.public_host(i % 3, LinkProfile::FIBER)).collect();
        let net = t.build(seed);
        let mut world = World::new(net);
        let addrs: Rc<Vec<SimAddr>> =
            Rc::new(hosts.iter().map(|&h| SimAddr::new(h, CHAT_PORT)).collect());
        let mut ids: Vec<Option<EndpointId>> = (0..N)
            .map(|i| {
                Some(Chatter::spawn(
                    &mut world,
                    addrs[i],
                    addrs.clone(),
                    seed ^ ((i as u64) << 8),
                ))
            })
            .collect();
        let mut incarnation = vec![0u64; N];

        let mut plan = ChurnPlan::poisson(
            &ChurnConfig {
                nodes: N,
                protected: 0,
                start: 2 * SECOND,
                end: 25 * SECOND,
                session_half_life: 8 * SECOND,
                downtime_mean: 3 * SECOND,
                crash_fraction: 0.5,
            },
            seed,
        );
        let respawn_addrs = addrs.clone();
        world.run_with_churn(&mut plan, 30 * SECOND, |w, ev| match ev.action {
            ChurnAction::Leave | ChurnAction::Crash => {
                if let Some(id) = ids[ev.node].take() {
                    w.remove_endpoint(id);
                    w.net.unbind(respawn_addrs[ev.node]);
                }
            }
            ChurnAction::Join => {
                if ids[ev.node].is_none() {
                    incarnation[ev.node] += 1;
                    let s = seed
                        ^ ((ev.node as u64) << 8)
                        ^ (incarnation[ev.node] << 40);
                    ids[ev.node] = Some(Chatter::spawn(
                        w,
                        respawn_addrs[ev.node],
                        respawn_addrs.clone(),
                        s,
                    ));
                }
            }
        });
        (
            world.trace_digest(),
            world.net.stats.events_processed,
            world.net.stats.events_dropped_stale,
        )
    }

    #[test]
    fn wheel_reproduces_heap_trace_under_churn() {
        for seed in [7u64, 4242] {
            let (heap_digest, heap_events, heap_stale) =
                chatter_trace(QueueKind::Heap, seed);
            let (wheel_digest, wheel_events, wheel_stale) =
                chatter_trace(QueueKind::Wheel, seed);
            assert!(heap_events > 500, "scenario too quiet: {heap_events} events");
            assert!(
                heap_stale > 0,
                "churn produced no stale events — tombstoning untested"
            );
            assert_eq!(heap_events, wheel_events, "event count diverged (seed {seed})");
            assert_eq!(heap_stale, wheel_stale, "stale drops diverged (seed {seed})");
            assert_eq!(
                heap_digest, wheel_digest,
                "dispatch trace diverged between heap and wheel (seed {seed})"
            );
        }
    }

    #[test]
    fn trace_digest_is_seed_sensitive() {
        // Guard against a digest that trivially collapses: different seeds
        // must yield different traces on the same queue implementation.
        let (a, _, _) = chatter_trace(QueueKind::Wheel, 7);
        let (b, _, _) = chatter_trace(QueueKind::Wheel, 8);
        assert_ne!(a, b, "digest insensitive to workload");
    }
}
