//! Table 1: RPC throughput at 1000 concurrent calls (queries per second).
//!
//! Reproduces the paper's four network scenarios × two payload sizes.
//! QPS is measured in virtual time over the full stack (protobuf framing,
//! Noise-style AEAD, reliability, NAT-free paths); the Local row is also
//! bounded by per-host CPU/stack cost which the simulator models as link
//! serialization on loopback. Wall-clock throughput (how fast the real
//! stack pushes calls through one core) is reported alongside — that is
//! the number the zero-copy data path moves.
//!
//! Emits `BENCH_rpc_throughput.json` at the repo root so the perf
//! trajectory is tracked across PRs.
//!
//! Usage: cargo bench --bench rpc_throughput [-- --calls N --payload small|large|both]

use lattica::metrics::{Histogram, QpsMeter};
use lattica::node::{LatticaNode, NodeEvent};
use lattica::protocols::Ctx;
use lattica::rpc::RpcEvent;
use lattica::scenarios::{table1_world, EchoApp, NetScenario};
use lattica::netsim::SECOND;
use lattica::util::cli::Args;
use lattica::util::json::Json;

struct ScenarioResult {
    qps: f64,
    lat: Histogram,
    /// Wall-clock seconds spent driving the scenario.
    wall_secs: f64,
    calls: usize,
}

fn run_scenario(s: NetScenario, payload: usize, response: usize, calls: usize, concurrency: usize) -> ScenarioResult {
    let (mut world, client, server) = table1_world(s, 77);
    server.borrow_mut().app = Some(Box::new(EchoApp { response_size: response }));
    let server_peer = server.borrow().peer_id();

    // Shared payload: each call bumps a refcount instead of copying.
    let body: lattica::util::Buf = vec![0x5Au8; payload].into();
    let wall_start = std::time::Instant::now();
    let mut meter = QpsMeter::start(world.net.now());
    let mut lat = Histogram::new();
    let mut issued = 0usize;
    let mut done = 0usize;

    // Keep `concurrency` calls in flight until `calls` complete.
    let mut in_flight = 0usize;
    while done < calls {
        while in_flight < concurrency && issued < calls {
            let mut n = client.borrow_mut();
            let LatticaNode { swarm, rpc, .. } = &mut *n;
            let mut ctx = Ctx::new(swarm, &mut world.net);
            if rpc.call(&mut ctx, &server_peer, "bench", "echo", body.clone()).is_ok() {
                issued += 1;
                in_flight += 1;
            } else {
                break;
            }
        }
        world.run_for(SECOND / 1000);
        let evs = client.borrow_mut().drain_events();
        for e in evs {
            if let NodeEvent::Rpc(RpcEvent::Response { rtt, .. }) = e {
                done += 1;
                in_flight -= 1;
                meter.record(world.net.now());
                lat.record(rtt);
            } else if let NodeEvent::Rpc(RpcEvent::CallFailed { .. }) = e {
                in_flight -= 1;
            }
        }
        if world.net.now() > 600 * SECOND {
            break; // safety
        }
    }
    ScenarioResult {
        qps: meter.qps(),
        lat,
        wall_secs: wall_start.elapsed().as_secs_f64(),
        calls: done,
    }
}

fn main() {
    let args = Args::from_env();
    let calls = args.opt_usize("calls", 2000).unwrap();
    let concurrency = args.opt_usize("concurrency", 1000).unwrap();
    let small = 128usize;
    let large = 256 * 1024;

    println!("Table 1: Lattica RPC throughput at {concurrency} concurrent calls (QPS)");
    println!("{:<24} {:>14} {:>14}", "Network Scenario", "128 B payload", "256 KB payload");
    println!("{:-<54}", "");
    let paper = [
        (NetScenario::Local, 10_000.0, 850.0),
        (NetScenario::SameRegionLan, 8_000.0, 600.0),
        (NetScenario::SameRegionWan, 3_000.0, 280.0),
        (NetScenario::InterContinent, 1_200.0, 110.0),
    ];
    let mut rows = Vec::new();
    for (s, _, _) in paper {
        let mut rs = run_scenario(s, small, small, calls, concurrency);
        let mut rl = run_scenario(s, large, 128, calls / 4, concurrency);
        println!("{:<24} {:>14.0} {:>14.0}", s.label(), rs.qps, rl.qps);
        println!("    small: {}  [wall {:.2}s, {:.0} calls/wall-s]",
            rs.lat.summary(), rs.wall_secs, rs.calls as f64 / rs.wall_secs.max(1e-9));
        println!("    large: {}  [wall {:.2}s, {:.0} calls/wall-s]",
            rl.lat.summary(), rl.wall_secs, rl.calls as f64 / rl.wall_secs.max(1e-9));
        rows.push((s, rs, rl));
    }
    println!();
    println!("Paper reference:");
    for (s, ps, pl) in paper {
        println!("{:<24} {:>14.0} {:>14.0}", s.label(), ps, pl);
    }

    // Machine-readable result for cross-PR tracking.
    let json_rows: Vec<Json> = rows
        .iter_mut()
        .map(|(s, rs, rl)| {
            Json::obj(vec![
                ("scenario", Json::str(s.label())),
                ("qps_small", Json::num(rs.qps)),
                ("qps_large", Json::num(rl.qps)),
                ("p50_small_ns", Json::num(rs.lat.percentile(50.0) as f64)),
                ("p99_small_ns", Json::num(rs.lat.percentile(99.0) as f64)),
                ("wall_secs_small", Json::num(rs.wall_secs)),
                ("wall_secs_large", Json::num(rl.wall_secs)),
                ("calls_per_wall_sec_small", Json::num(rs.calls as f64 / rs.wall_secs.max(1e-9))),
                ("calls_per_wall_sec_large", Json::num(rl.calls as f64 / rl.wall_secs.max(1e-9))),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("rpc_throughput")),
        ("calls", Json::num(calls as f64)),
        ("concurrency", Json::num(concurrency as f64)),
        ("rows", Json::Arr(json_rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_rpc_throughput.json");
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // Shape checks across the three networked rows (LAN → WAN → inter-
    // continent must degrade in both payload classes). The Local row is
    // asserted only to be within the paper's order for small payloads:
    // its relation to LAN depends on whether per-host stack budgets are
    // shared (one machine) or independent (two) — see EXPERIMENTS.md.
    assert!(
        rows[1].1.qps > rows[2].1.qps && rows[2].1.qps > rows[3].1.qps,
        "128B QPS must degrade with network distance"
    );
    assert!(
        rows[1].2.qps > rows[3].2.qps,
        "256KB QPS must degrade with network distance"
    );
    assert!(
        rows[0].1.qps > 1000.0,
        "Local small-payload QPS must be in the paper's order (>1k)"
    );
    println!("\nshape check OK: QPS degrades with network distance in both payload classes");
}
