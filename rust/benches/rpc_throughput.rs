//! Table 1: RPC throughput at 1000 concurrent calls (queries per second).
//!
//! Reproduces the paper's four network scenarios × two payload sizes,
//! plus two WAN stress rows (LossyWan, Bufferbloat) that exercise the
//! congestion-control subsystem: CUBIC and NewReno are compared against
//! the seed's fixed 16 MB window, and each row exports transport health
//! (cwnd, srtt, retransmitted bytes, loss events, pacer pressure).
//!
//! A priority-scheduler check runs on the lossy WAN: ping p99 is measured
//! idle and again under a concurrent bulk Bitswap sync — the bulk class
//! must not starve control traffic.
//!
//! A retry-policy arm compares no-retry vs retry vs retry+hedging stubs
//! on the lossy WAN: tail latency (p99) under loss is the paper's
//! motivation for a real stub layer, and the hedged arm must strictly
//! beat the no-retry baseline.
//!
//! Emits `BENCH_rpc_throughput.json` at the repo root so the perf
//! trajectory is tracked across PRs.
//!
//! Usage: cargo bench --bench rpc_throughput [-- --calls N]

use lattica::metrics::{Histogram, QpsMeter, StubStats, TransportHealth};
use lattica::netsim::{MILLI, SECOND};
use lattica::node::{LatticaNode, NodeEvent};
use lattica::protocols::ping::PingEvent;
use lattica::protocols::Ctx;
use lattica::rpc::{CallOptions, HedgePolicy, RetryPolicy, Status, Stub};
use lattica::scenarios::{echo_service, overload_scenario, table1_world_cc, NetScenario, OverloadConfig};
use lattica::transport::CcAlgorithm;
use lattica::util::cli::Args;
use lattica::util::json::Json;

struct ScenarioResult {
    qps: f64,
    lat: Histogram,
    /// Wall-clock seconds spent driving the scenario.
    wall_secs: f64,
    calls: usize,
    /// Client-side transport health at the end of the run.
    health: TransportHealth,
    /// Client-side stub counters (attempts, retries, hedges…).
    stub: StubStats,
}

fn run_scenario_opts(
    s: NetScenario,
    cc: CcAlgorithm,
    payload: usize,
    response: usize,
    calls: usize,
    concurrency: usize,
    opts: CallOptions,
) -> ScenarioResult {
    let (mut world, client, server) = table1_world_cc(s, 77, cc);
    server.borrow_mut().register_service(echo_service(response));
    let server_peer = server.borrow().peer_id();
    let mut stub = Stub::new("bench", vec![server_peer]).with_options(opts);

    // Shared payload: each call bumps a refcount instead of copying.
    let body: lattica::util::Buf = vec![0x5Au8; payload].into();
    let wall_start = std::time::Instant::now();
    let mut meter = QpsMeter::start(world.net.now());
    let mut lat = Histogram::new();
    let mut issued = 0usize;
    let mut done = 0usize;

    // Keep `concurrency` logical calls in flight until `calls` complete.
    let mut in_flight = 0usize;
    while done < calls {
        while in_flight < concurrency && issued < calls {
            let mut n = client.borrow_mut();
            stub.call(&mut n, &mut world.net, "echo", body.clone());
            issued += 1;
            in_flight += 1;
        }
        world.run_for(SECOND / 1000);
        let evs = client.borrow_mut().drain_events();
        {
            let mut n = client.borrow_mut();
            for e in &evs {
                stub.on_node_event(&mut n, &mut world.net, e);
            }
            stub.tick(&mut n, &mut world.net);
        }
        while let Some(d) = stub.poll_done() {
            in_flight -= 1;
            if d.status == Status::Ok {
                done += 1;
                meter.record(world.net.now());
                lat.record(d.rtt);
            }
        }
        if world.net.now() > 600 * SECOND {
            break; // safety
        }
    }
    let health = client.borrow().swarm.transport_health();
    ScenarioResult {
        qps: meter.qps(),
        lat,
        wall_secs: wall_start.elapsed().as_secs_f64(),
        calls: done,
        health,
        stub: stub.stats,
    }
}

fn run_scenario(
    s: NetScenario,
    cc: CcAlgorithm,
    payload: usize,
    response: usize,
    calls: usize,
    concurrency: usize,
) -> ScenarioResult {
    run_scenario_opts(s, cc, payload, response, calls, concurrency, CallOptions::default())
}

/// Ping p99 on the lossy WAN, optionally under a concurrent bulk Bitswap
/// sync (an 8 MB blob). Exercises the priority-aware stream scheduler:
/// bulk must not starve the control class.
fn ping_p99_lossy(with_bulk: bool) -> u64 {
    let (mut world, client, server) =
        table1_world_cc(NetScenario::LossyWan, 91, CcAlgorithm::Cubic);
    // Parameter-server fetch only: this measures scheduler priority, so
    // keep swarm-mode DHT discovery/announce traffic out of the baseline.
    client.borrow_mut().cfg.swarm_sync = false;
    let server_peer = server.borrow().peer_id();
    let root = if with_bulk {
        let blob: Vec<u8> = (0..8_000_000u32).map(|i| (i % 241) as u8).collect();
        Some(server.borrow_mut().publish_blob(&mut world.net, "bulk", 1, &blob, 256 * 1024))
    } else {
        None
    };
    let mut lat = Histogram::new();
    let mut next_ping = world.net.now();
    let deadline = world.net.now() + 30 * SECOND;
    while world.net.now() < deadline {
        if let Some(root) = root {
            client.borrow_mut().sync_blob(&mut world.net, root, &[server_peer]);
        }
        if world.net.now() >= next_ping {
            let mut n = client.borrow_mut();
            let LatticaNode { swarm, ping, .. } = &mut *n;
            let mut ctx = Ctx::new(swarm, &mut world.net);
            let _ = ping.ping(&mut ctx, &server_peer);
            next_ping = world.net.now() + 250 * MILLI;
        }
        world.run_for(20 * MILLI);
        for e in client.borrow_mut().drain_events() {
            if let NodeEvent::Ping(PingEvent::Rtt { rtt, .. }) = e {
                lat.record(rtt);
            }
        }
    }
    // Total starvation must fail loudly, not report p99 = 0.
    assert!(
        lat.len() >= 30,
        "only {} ping RTTs measured (with_bulk={with_bulk}) — pings starved?",
        lat.len()
    );
    lat.percentile(99.0)
}

fn health_fields(h: &TransportHealth) -> Vec<(&'static str, Json)> {
    vec![
        ("cwnd", Json::num(h.mean_cwnd() as f64)),
        ("srtt_ns", Json::num(h.mean_srtt() as f64)),
        ("retx_bytes", Json::num(h.bytes_retransmitted as f64)),
        ("loss_events", Json::num(h.loss_events as f64)),
        ("fast_retransmits", Json::num(h.fast_retransmits as f64)),
        ("rto_events", Json::num(h.rto_events as f64)),
        ("pacer_utilization", Json::num(h.mean_pacer_utilization())),
    ]
}

fn main() {
    let args = Args::from_env();
    let calls = args.opt_usize("calls", 2000).unwrap();
    let concurrency = args.opt_usize("concurrency", 1000).unwrap();
    let small = 128usize;
    let large = 256 * 1024;

    println!("Table 1: Lattica RPC throughput at {concurrency} concurrent calls (QPS)");
    println!("{:<24} {:>14} {:>14}", "Network Scenario", "128 B payload", "256 KB payload");
    println!("{:-<54}", "");
    let paper = [
        (NetScenario::Local, 10_000.0, 850.0),
        (NetScenario::SameRegionLan, 8_000.0, 600.0),
        (NetScenario::SameRegionWan, 3_000.0, 280.0),
        (NetScenario::InterContinent, 1_200.0, 110.0),
    ];
    let mut rows = Vec::new();
    for (s, _, _) in paper {
        let mut rs = run_scenario(s, CcAlgorithm::Cubic, small, small, calls, concurrency);
        let mut rl = run_scenario(s, CcAlgorithm::Cubic, large, 128, calls / 4, concurrency);
        println!("{:<24} {:>14.0} {:>14.0}", s.label(), rs.qps, rl.qps);
        println!("    small: {}  [wall {:.2}s, {:.0} calls/wall-s]",
            rs.lat.summary(), rs.wall_secs, rs.calls as f64 / rs.wall_secs.max(1e-9));
        println!("    large: {}  [wall {:.2}s, {:.0} calls/wall-s]",
            rl.lat.summary(), rl.wall_secs, rl.calls as f64 / rl.wall_secs.max(1e-9));
        rows.push((s, rs, rl));
    }
    println!();
    println!("Paper reference:");
    for (s, ps, pl) in paper {
        println!("{:<24} {:>14.0} {:>14.0}", s.label(), ps, pl);
    }

    // WAN stress: congestion control comparison, 256 KB payloads.
    println!();
    println!("WAN stress (256 KB payload QPS by congestion controller):");
    println!("{:<28} {:>10} {:>10} {:>10}", "Scenario", "fixed", "newreno", "cubic");
    let mut stress_rows: Vec<Json> = Vec::new();
    for s in [NetScenario::LossyWan, NetScenario::Bufferbloat] {
        let mut qps = Vec::new();
        for cc in [CcAlgorithm::Fixed, CcAlgorithm::NewReno, CcAlgorithm::Cubic] {
            let mut r = run_scenario(s, cc, large, 128, (calls / 8).max(50), concurrency.min(128));
            qps.push(r.qps);
            let mut fields = vec![
                ("scenario", Json::str(s.label())),
                ("cc", Json::str(cc.name())),
                ("qps_large", Json::num(r.qps)),
                ("p50_large_ns", Json::num(r.lat.percentile(50.0) as f64)),
                ("p99_large_ns", Json::num(r.lat.percentile(99.0) as f64)),
                ("wall_secs", Json::num(r.wall_secs)),
            ];
            fields.extend(health_fields(&r.health));
            stress_rows.push(Json::obj(fields));
        }
        println!("{:<28} {:>10.1} {:>10.1} {:>10.1}", s.label(), qps[0], qps[1], qps[2]);
    }

    // Retry-policy arms on the lossy WAN: the stub's no-retry baseline vs
    // idempotent retries vs retries + hedging. Same seed per arm, so the
    // loss pattern is identical and only the policy differs.
    let pcalls = (calls / 4).max(200);
    println!();
    println!("LossyWan policy arms (128 B payload, {pcalls} calls, concurrency 32):");
    println!(
        "{:<14} {:>10} {:>12} {:>12} {:>9} {:>8}",
        "policy", "qps", "p50", "p99", "attempts", "hedges"
    );
    let retry_policy = RetryPolicy {
        max_attempts: 4,
        base_backoff: 50 * MILLI,
        max_backoff: SECOND,
        jitter: 0.5,
        ..RetryPolicy::none()
    };
    let policies: Vec<(&str, CallOptions)> = vec![
        ("none", CallOptions::default()),
        (
            "retry",
            CallOptions {
                attempt_timeout: Some(500 * MILLI),
                retry: retry_policy,
                ..CallOptions::default()
            },
        ),
        (
            "retry+hedge",
            CallOptions {
                attempt_timeout: Some(500 * MILLI),
                retry: retry_policy,
                hedge: HedgePolicy::on(),
                ..CallOptions::default()
            },
        ),
    ];
    let mut policy_rows: Vec<Json> = Vec::new();
    let mut policy_p99: Vec<u64> = Vec::new();
    for (name, opts) in policies {
        let mut r = run_scenario_opts(
            NetScenario::LossyWan,
            CcAlgorithm::Cubic,
            small,
            small,
            pcalls,
            32,
            opts,
        );
        let p50 = r.lat.percentile(50.0);
        let p99 = r.lat.percentile(99.0);
        println!(
            "{:<14} {:>10.1} {:>12} {:>12} {:>9} {:>8}",
            name,
            r.qps,
            lattica::util::timefmt::fmt_ns(p50),
            lattica::util::timefmt::fmt_ns(p99),
            r.stub.attempts,
            r.stub.hedges
        );
        println!("    stub: {}", r.stub.summary());
        policy_rows.push(Json::obj(vec![
            ("scenario", Json::str(NetScenario::LossyWan.label())),
            ("policy", Json::str(name)),
            ("qps", Json::num(r.qps)),
            ("p50_ns", Json::num(p50 as f64)),
            ("p99_ns", Json::num(p99 as f64)),
            ("ok_calls", Json::num(r.calls as f64)),
            ("attempts", Json::num(r.stub.attempts as f64)),
            ("retries", Json::num(r.stub.retries as f64)),
            ("hedges", Json::num(r.stub.hedges as f64)),
            ("hedge_wins", Json::num(r.stub.hedge_wins as f64)),
            ("failovers", Json::num(r.stub.failovers as f64)),
            ("deadline_expired", Json::num(r.stub.deadline_expired as f64)),
        ]));
        policy_p99.push(p99);
    }

    // Priority scheduler: bulk Bitswap must not starve pings.
    let ping_idle = ping_p99_lossy(false);
    let ping_bulk = ping_p99_lossy(true);
    let ping_ratio = ping_bulk as f64 / ping_idle.max(1) as f64;
    println!();
    println!(
        "Priority check (LossyWan): ping p99 idle {} vs under bulk sync {} ({:.2}x)",
        lattica::util::timefmt::fmt_ns(ping_idle),
        lattica::util::timefmt::fmt_ns(ping_bulk),
        ping_ratio
    );

    // Overload survival: drive a 10× surge through admission control,
    // WFQ queues and server pushback, and check the metastable-failure
    // bars (goodput holds through the surge, shedding is pre-decode
    // cheap, the system recovers without operator action).
    let overload = overload_scenario(&OverloadConfig::default());
    println!();
    println!(
        "Overload survival (capacity {:.0} qps, nominal {:.0} qps):",
        overload.capacity_qps, overload.nominal_capacity_qps
    );
    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>9} {:>12} {:>10} {:>12}",
        "phase", "offered", "goodput", "ok", "rejected", "shed_pre", "shed_q", "p99_ok"
    );
    let mut overload_rows: Vec<Json> = Vec::new();
    for r in &overload.rows {
        println!(
            "{:<10} {:>10.0} {:>10.0} {:>8} {:>9} {:>12} {:>10} {:>12}",
            r.phase,
            r.offered_qps,
            r.goodput_qps,
            r.ok,
            r.rejected,
            r.shed_predecode,
            r.shed_queue,
            lattica::util::timefmt::fmt_ns(r.p99_admitted_ns)
        );
        overload_rows.push(Json::obj(vec![
            ("phase", Json::str(r.phase)),
            ("offered_qps", Json::num(r.offered_qps)),
            ("goodput_qps", Json::num(r.goodput_qps)),
            ("ok", Json::num(r.ok as f64)),
            ("rejected", Json::num(r.rejected as f64)),
            ("shed_predecode", Json::num(r.shed_predecode as f64)),
            ("shed_queue", Json::num(r.shed_queue as f64)),
            ("p99_admitted_ns", Json::num(r.p99_admitted_ns as f64)),
        ]));
    }
    println!(
        "    stub: {}\n    router: {}",
        overload.stub.summary(),
        overload.router.summary()
    );

    // Machine-readable result for cross-PR tracking.
    let json_rows: Vec<Json> = rows
        .iter_mut()
        .map(|(s, rs, rl)| {
            let mut fields = vec![
                ("scenario", Json::str(s.label())),
                ("cc", Json::str("cubic")),
                ("qps_small", Json::num(rs.qps)),
                ("qps_large", Json::num(rl.qps)),
                ("p50_small_ns", Json::num(rs.lat.percentile(50.0) as f64)),
                ("p99_small_ns", Json::num(rs.lat.percentile(99.0) as f64)),
                ("wall_secs_small", Json::num(rs.wall_secs)),
                ("wall_secs_large", Json::num(rl.wall_secs)),
                ("calls_per_wall_sec_small", Json::num(rs.calls as f64 / rs.wall_secs.max(1e-9))),
                ("calls_per_wall_sec_large", Json::num(rl.calls as f64 / rl.wall_secs.max(1e-9))),
            ];
            fields.extend(health_fields(&rl.health));
            Json::obj(fields)
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("rpc_throughput")),
        ("calls", Json::num(calls as f64)),
        ("concurrency", Json::num(concurrency as f64)),
        ("rows", Json::Arr(json_rows)),
        ("wan_stress_rows", Json::Arr(stress_rows)),
        ("policy_rows", Json::Arr(policy_rows)),
        ("overload_rows", Json::Arr(overload_rows)),
        ("overload_capacity_qps", Json::num(overload.capacity_qps)),
        ("overload_nominal_capacity_qps", Json::num(overload.nominal_capacity_qps)),
        ("overload_shed_predecode", Json::num(overload.shed_predecode as f64)),
        ("overload_shed_queue", Json::num(overload.shed_queue as f64)),
        ("overload_replies_dropped", Json::num(overload.replies_dropped as f64)),
        ("ping_p99_idle_ns", Json::num(ping_idle as f64)),
        ("ping_p99_under_bulk_ns", Json::num(ping_bulk as f64)),
        ("ping_p99_bulk_ratio", Json::num(ping_ratio)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_rpc_throughput.json");
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // Shape checks across the three networked rows (LAN → WAN → inter-
    // continent must degrade in both payload classes). The Local row is
    // asserted only to be within the paper's order for small payloads:
    // its relation to LAN depends on whether per-host stack budgets are
    // shared (one machine) or independent (two) — see EXPERIMENTS.md.
    assert!(
        rows[1].1.qps > rows[2].1.qps && rows[2].1.qps > rows[3].1.qps,
        "128B QPS must degrade with network distance"
    );
    assert!(
        rows[1].2.qps > rows[3].2.qps,
        "256KB QPS must degrade with network distance"
    );
    assert!(
        rows[0].1.qps > 1000.0,
        "Local small-payload QPS must be in the paper's order (>1k)"
    );
    assert!(
        ping_ratio <= 2.0,
        "bulk sync must not more than double ping p99 (got {ping_ratio:.2}x)"
    );
    assert!(
        policy_p99[2] < policy_p99[0],
        "retry+hedging must strictly beat the no-retry p99 under loss: hedge {} vs none {}",
        lattica::util::timefmt::fmt_ns(policy_p99[2]),
        lattica::util::timefmt::fmt_ns(policy_p99[0]),
    );
    let surge = overload
        .rows
        .iter()
        .find(|r| r.phase == "surge")
        .expect("overload scenario emits a surge row");
    assert!(
        surge.goodput_qps >= 0.8 * overload.capacity_qps,
        "surge goodput {:.0} qps must hold ≥80% of measured capacity {:.0} qps",
        surge.goodput_qps,
        overload.capacity_qps
    );
    let total_shed = overload.shed_predecode + overload.shed_queue;
    assert!(
        total_shed == 0 || overload.shed_predecode * 10 >= total_shed * 9,
        "shedding must be pre-decode cheap: {} of {} shed before payload decode",
        overload.shed_predecode,
        total_shed
    );
    println!("\nshape check OK: QPS degrades with network distance in both payload classes");
    println!(
        "policy check OK: hedged p99 {} < no-retry p99 {} on the lossy WAN",
        lattica::util::timefmt::fmt_ns(policy_p99[2]),
        lattica::util::timefmt::fmt_ns(policy_p99[0]),
    );
}
