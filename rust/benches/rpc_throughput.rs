//! Table 1: RPC throughput at 1000 concurrent calls (queries per second).
//!
//! Reproduces the paper's four network scenarios × two payload sizes.
//! QPS is measured in virtual time over the full stack (protobuf framing,
//! Noise-style AEAD, reliability, NAT-free paths); the Local row is also
//! bounded by per-host CPU/stack cost which the simulator models as link
//! serialization on loopback.
//!
//! Usage: cargo bench --bench rpc_throughput [-- --calls N --payload small|large|both]

use lattica::metrics::{Histogram, QpsMeter};
use lattica::node::{LatticaNode, NodeEvent};
use lattica::protocols::Ctx;
use lattica::rpc::RpcEvent;
use lattica::scenarios::{table1_world, EchoApp, NetScenario};
use lattica::netsim::SECOND;
use lattica::util::cli::Args;

fn run_scenario(s: NetScenario, payload: usize, response: usize, calls: usize, concurrency: usize) -> (f64, Histogram) {
    let (mut world, client, server) = table1_world(s, 77);
    server.borrow_mut().app = Some(Box::new(EchoApp { response_size: response }));
    let server_peer = server.borrow().peer_id();

    let body = vec![0x5Au8; payload];
    let mut meter = QpsMeter::start(world.net.now());
    let mut lat = Histogram::new();
    let mut issued = 0usize;
    let mut done = 0usize;

    // Keep `concurrency` calls in flight until `calls` complete.
    let mut in_flight = 0usize;
    while done < calls {
        while in_flight < concurrency && issued < calls {
            let mut n = client.borrow_mut();
            let LatticaNode { swarm, rpc, .. } = &mut *n;
            let mut ctx = Ctx::new(swarm, &mut world.net);
            if rpc.call(&mut ctx, &server_peer, "bench", "echo", &body).is_ok() {
                issued += 1;
                in_flight += 1;
            } else {
                break;
            }
        }
        world.run_for(SECOND / 1000);
        let evs = client.borrow_mut().drain_events();
        for e in evs {
            if let NodeEvent::Rpc(RpcEvent::Response { rtt, .. }) = e {
                done += 1;
                in_flight -= 1;
                meter.record(world.net.now());
                lat.record(rtt);
            } else if let NodeEvent::Rpc(RpcEvent::CallFailed { .. }) = e {
                in_flight -= 1;
            }
        }
        if world.net.now() > 600 * SECOND {
            break; // safety
        }
    }
    (meter.qps(), lat)
}

fn main() {
    let args = Args::from_env();
    let calls = args.opt_usize("calls", 2000).unwrap();
    let concurrency = args.opt_usize("concurrency", 1000).unwrap();
    let small = 128usize;
    let large = 256 * 1024;

    println!("Table 1: Lattica RPC throughput at {concurrency} concurrent calls (QPS)");
    println!("{:<24} {:>14} {:>14}", "Network Scenario", "128 B payload", "256 KB payload");
    println!("{:-<54}", "");
    let paper = [
        (NetScenario::Local, 10_000.0, 850.0),
        (NetScenario::SameRegionLan, 8_000.0, 600.0),
        (NetScenario::SameRegionWan, 3_000.0, 280.0),
        (NetScenario::InterContinent, 1_200.0, 110.0),
    ];
    let mut rows = Vec::new();
    for (s, _, _) in paper {
        let (qps_s, mut lat_s) = run_scenario(s, small, small, calls, concurrency);
        let (qps_l, mut lat_l) = run_scenario(s, large, 128, calls / 4, concurrency);
        println!("{:<24} {:>14.0} {:>14.0}", s.label(), qps_s, qps_l);
        println!("    small: {}", lat_s.summary());
        println!("    large: {}", lat_l.summary());
        rows.push((s, qps_s, qps_l));
    }
    println!();
    println!("Paper reference:");
    for (s, ps, pl) in paper {
        println!("{:<24} {:>14.0} {:>14.0}", s.label(), ps, pl);
    }
    // Shape checks across the three networked rows (LAN → WAN → inter-
    // continent must degrade in both payload classes). The Local row is
    // asserted only to be within the paper's order for small payloads:
    // its relation to LAN depends on whether per-host stack budgets are
    // shared (one machine) or independent (two) — see EXPERIMENTS.md.
    assert!(
        rows[1].1 > rows[2].1 && rows[2].1 > rows[3].1,
        "128B QPS must degrade with network distance"
    );
    assert!(
        rows[1].2 > rows[3].2,
        "256KB QPS must degrade with network distance"
    );
    assert!(
        rows[0].1 > 1000.0,
        "Local small-payload QPS must be in the paper's order (>1k)"
    );
    println!("\nshape check OK: QPS degrades with network distance in both payload classes");
}
