//! Fig. 1(2): decentralized CDN — chunked, CID-addressed assets synced via
//! Bitswap vs a single-origin client-server baseline.
//!
//! N fetchers retrieve a chunked asset. In the Lattica configuration every
//! node that completes becomes a provider (fetchers re-stripe across all
//! known providers); the baseline forces everyone to fetch from the origin
//! alone. Reports time-to-full-replication and origin egress.

use lattica::content::DagManifest;
use lattica::netsim::topology::LinkProfile;
use lattica::netsim::SECOND;
use lattica::node::run_until;
use lattica::scenarios::bootstrap_mesh;
use lattica::util::cli::Args;
use lattica::util::timefmt;

fn run(n_fetchers: usize, asset_mb: usize, p2p: bool, seed: u64) -> (f64, u64) {
    let (mut world, nodes) = bootstrap_mesh(n_fetchers + 1, seed, LinkProfile::FIBER);
    let data: Vec<u8> = {
        let mut rng = lattica::util::Rng::new(seed ^ 0xA55E7);
        rng.gen_bytes(asset_mb * 1024 * 1024)
    };
    let root = nodes[0]
        .borrow_mut()
        .publish_blob(&mut world.net, "asset", 1, &data, 256 * 1024);
    world.run_for(SECOND);
    let origin = nodes[0].borrow().peer_id();
    let t0 = world.net.now();

    // All fetchers start at once: manifest first, then chunks.
    for f in &nodes[1..] {
        f.borrow_mut().fetch_blob(&mut world.net, root, vec![origin]);
    }
    run_until(&mut world, 30 * SECOND, || {
        nodes[1..].iter().all(|f| f.borrow().blockstore.has(&root))
    });
    for (i, f) in nodes[1..].iter().enumerate() {
        let providers = if p2p {
            // Everyone is a potential provider (swarm-style striping).
            nodes
                .iter()
                .enumerate()
                .filter(|(j, _)| *j != i + 1)
                .map(|(_, nd)| nd.borrow().peer_id())
                .collect()
        } else {
            vec![origin]
        };
        f.borrow_mut()
            .fetch_manifest_chunks(&mut world.net, &root, providers)
            .unwrap();
    }
    let ok = run_until(&mut world, 600 * SECOND, || {
        nodes[1..].iter().all(|f| {
            let nd = f.borrow();
            DagManifest::load(&nd.blockstore, &root)
                .map(|m| m.is_complete(&nd.blockstore))
                .unwrap_or(false)
        })
    });
    assert!(ok, "replication did not complete");
    let elapsed = (world.net.now() - t0) as f64 / 1e9;
    // Origin egress: bytes served by node 0's bitswap ledgers.
    let origin_egress: u64 = nodes[0]
        .borrow()
        .bitswap
        .ledgers
        .values()
        .map(|l| l.bytes_sent)
        .sum();
    (elapsed, origin_egress)
}

fn main() {
    let args = Args::from_env();
    let asset_mb = args.opt_usize("asset-mb", 8).unwrap();
    println!("Fig 1(2): decentralized CDN — {asset_mb} MiB asset, 256 KiB chunks");
    println!(
        "{:<10} {:>16} {:>18} {:>16} {:>18}",
        "fetchers", "p2p time", "p2p origin-out", "central time", "central origin-out"
    );
    let mut rows = Vec::new();
    for n in [2usize, 4, 8, 16] {
        let (t_p2p, e_p2p) = run(n, asset_mb, true, 91 + n as u64);
        let (t_c, e_c) = run(n, asset_mb, false, 191 + n as u64);
        println!(
            "{:<10} {:>14.2}s {:>18} {:>14.2}s {:>18}",
            n,
            t_p2p,
            timefmt::fmt_bytes(e_p2p),
            t_c,
            timefmt::fmt_bytes(e_c)
        );
        rows.push((n, t_p2p, e_p2p, t_c, e_c));
    }
    // Shape: with many fetchers, p2p saves origin egress and is no slower.
    let last = rows.last().unwrap();
    assert!(
        last.2 < last.4,
        "p2p must reduce origin egress at n={} ({} vs {})",
        last.0,
        last.2,
        last.4
    );
    println!("\nshape check OK: swarm striping offloads the origin as the swarm grows");
}
