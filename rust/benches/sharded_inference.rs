//! Fig. 1(4): latency-aware sharded inference — emits
//! `BENCH_sharded_inference.json`.
//!
//! Three arms over the same geo-distributed deployment
//! ([`lattica::scenarios::route_inference`]): every pipeline stage has a
//! replica in the client's region and one across a continent.
//!
//! 1. **static** — placement-blind chain pinned to each stage's
//!    first-registered (remote) holder: the pre-router baseline;
//! 2. **routed** — chain assembled from live layer ads + measured RTTs;
//! 3. **routed_kill** — routed, with the middle stage's local replica
//!    killed mid-stream: splice-repair + replay must complete every
//!    request with zero client-visible failures and zero duplicate KV
//!    appends.
//!
//! Needs no `make artifacts`: with a manifest present its dims (clamped)
//! shape the synthetic model, otherwise `SimModel::tiny()` — rows are
//! emitted either way.

use lattica::route::SimModel;
use lattica::runtime::Manifest;
use lattica::scenarios::{route_inference, RouteOutcome, RouteScenarioConfig};
use lattica::util::cli::Args;
use lattica::util::json::Json;

/// Model shape for the run: AOT manifest dims when artifacts exist
/// (clamped — the synthetic recurrence only needs the shape), else the
/// built-in tiny model.
fn bench_model() -> SimModel {
    match Manifest::load("artifacts") {
        Ok(m) => {
            // Multiple of 6 so the layer range splits evenly across the
            // quick (2) and ci (3) stage counts.
            let n_layer = ((m.config.n_layer.clamp(6, 24) / 6) * 6) as u32;
            let d_model = m.config.d_model.clamp(4, 64);
            let vocab = m.config.vocab.clamp(16, 512) as u32;
            SimModel {
                model_id: format!("aot-{n_layer}l-{d_model}d"),
                n_layer,
                d_model,
                vocab,
            }
        }
        Err(_) => SimModel::tiny(),
    }
}

fn run_arm(
    name: &str,
    model: &SimModel,
    routed: bool,
    kill: bool,
    quick: bool,
) -> (RouteOutcome, Json) {
    let mut cfg = if quick {
        RouteScenarioConfig::quick(routed, kill)
    } else {
        RouteScenarioConfig::ci(routed, kill)
    };
    cfg.model = model.clone();
    let mut out = route_inference(&cfg);
    let p50 = out.ttft.percentile(50.0) as f64 / 1e6;
    let p99 = out.ttft.percentile(99.0) as f64 / 1e6;
    println!(
        "  {name:<12} {}/{} completed  ttft p50 {p50:.2} ms  p99 {p99:.2} ms  \
         {:.1} tok/s  repairs {}  dup-appends {}  dht holders {}",
        out.completed, out.requests, out.tokens_per_sec, out.repairs, out.duplicate_appends,
        out.dht_holders
    );
    let row = Json::obj(vec![
        ("arm", Json::str(name)),
        ("requests", Json::num(out.requests as f64)),
        ("completed", Json::num(out.completed as f64)),
        ("failed", Json::num(out.failed as f64)),
        ("ttft_p50_ms", Json::num(p50)),
        ("ttft_p99_ms", Json::num(p99)),
        ("tokens_per_sec", Json::num(out.tokens_per_sec)),
        ("repairs", Json::num(out.repairs as f64)),
        ("duplicate_appends", Json::num(out.duplicate_appends as f64)),
        ("kv_peak", Json::num(out.kv_peak as f64)),
        ("dht_holders", Json::num(out.dht_holders as f64)),
        ("reference_match", Json::Bool(out.reference_match)),
    ]);
    (out, row)
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let model = bench_model();
    println!(
        "sharded inference over {} ({} layers, d_model {}, vocab {}):",
        model.model_id, model.n_layer, model.d_model, model.vocab
    );

    let (static_out, static_row) = run_arm("static", &model, false, false, quick);
    let (routed_out, routed_row) = run_arm("routed", &model, true, false, quick);
    let (kill_out, kill_row) = run_arm("routed_kill", &model, true, true, quick);

    let mut s = static_out;
    let mut r = routed_out;
    let doc = Json::obj(vec![
        ("bench", Json::str("sharded_inference")),
        ("model", Json::str(&model.model_id)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Arr(vec![static_row, routed_row, kill_row])),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sharded_inference.json");
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // --- Shape checks (after the JSON lands, so failures still publish) -----
    for (name, o) in [("static", &s), ("routed", &r), ("routed_kill", &kill_out)] {
        assert_eq!(o.failed, 0, "{name}: client-visible failures");
        assert_eq!(o.completed, o.requests, "{name}: incomplete requests");
        assert!(o.reference_match, "{name}: output diverged from the oracle");
    }
    assert!(
        r.ttft.percentile(99.0) < s.ttft.percentile(99.0),
        "routed p99 TTFT must beat the static chain"
    );
    assert!(
        r.tokens_per_sec > s.tokens_per_sec,
        "routed tokens/sec must beat the static chain"
    );
    assert!(r.dht_holders >= 1, "no DHT providers for the layer bucket");
    assert!(kill_out.repairs >= 1, "kill arm performed no chain repair");
    assert_eq!(kill_out.duplicate_appends, 0, "replay double-appended KV entries");
    println!("shape check OK: routed beats static; kill masked by splice-repair + replay");
}
