//! Fig. 1(4): sharded AI inference over RPC streams with fault-tolerant
//! shard nodes.
//!
//! Builds a 2-stage pipeline of the real AOT transformer (requires
//! `make artifacts`), each stage replicated ×2, serves a request batch,
//! then kills a shard mid-run and shows the shard-aware stub failing over
//! with zero failed requests.

use lattica::netsim::topology::LinkProfile;
use lattica::netsim::SECOND;
use lattica::node::NodeEvent;
use lattica::runtime::Engine;
use lattica::scenarios::bootstrap_mesh;
use lattica::shard::{PipelineClient, ShardServer};
use lattica::util::cli::Args;
use std::cell::RefCell;
use std::rc::Rc;

fn main() {
    let args = Args::from_env();
    let requests = args.opt_usize("requests", 24).unwrap();
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("sharded_inference: artifacts missing; run `make artifacts` first");
        return;
    }
    let engine = Rc::new(RefCell::new(Engine::load(dir).expect("engine")));
    let cfg = engine.borrow().manifest.config.clone();
    let params = engine.borrow().manifest.load_init_params().unwrap();
    let n_layers = cfg.n_layer;
    let split = n_layers / 2;

    // Nodes: 1 client + 2 stages × 2 replicas.
    let (mut world, nodes) = bootstrap_mesh(5, 2024, LinkProfile::DATACENTER);
    let client = nodes[0].clone();
    let stage_peers: Vec<Vec<_>> = vec![
        vec![nodes[1].borrow().peer_id(), nodes[2].borrow().peer_id()],
        vec![nodes[3].borrow().peer_id(), nodes[4].borrow().peer_id()],
    ];
    for (i, nd) in nodes[1..].iter().enumerate() {
        let stage = i / 2;
        let server = ShardServer::new(
            engine.clone(),
            if stage == 0 { (0, split) } else { (split, n_layers) },
            stage == 0,
            stage == 1,
            params.clone(),
        );
        let (svc, _handle) = server.into_service();
        nd.borrow_mut().register_service(svc);
    }
    world.run_for(SECOND);

    let mut pipeline = PipelineClient::new(stage_peers);
    let tokens: Vec<i32> = (0..cfg.seq_len as i32).map(|i| (i * 3 + 1) % cfg.vocab as i32).collect();

    // Phase 1: half the requests with all replicas healthy.
    let wall = std::time::Instant::now();
    let t0 = world.net.now();
    for _ in 0..requests / 2 {
        let mut c = client.borrow_mut();
        pipeline.infer(&mut c, &mut world.net, tokens.clone()).unwrap();
    }
    let deadline = world.net.now() + 60 * SECOND;
    while pipeline.completed.len() < requests / 2 && world.net.now() < deadline {
        world.run_for(SECOND / 50);
        let evs = client.borrow_mut().drain_events();
        let mut c = client.borrow_mut();
        for e in &evs {
            if let NodeEvent::Rpc(ev) = e {
                pipeline.on_rpc_event(&mut c, &mut world.net, ev);
            }
        }
        pipeline.tick(&mut c, &mut world.net);
    }
    let healthy_done = pipeline.completed.len();
    let healthy_virt = (world.net.now() - t0) as f64 / 1e9;

    // Phase 2: kill replica 0 of stage 1 mid-run.
    let dead = nodes[3].borrow().endpoint_id();
    world.remove_endpoint(dead);
    println!("killed stage-1 replica 0 (endpoint {dead})");

    for _ in 0..requests / 2 {
        let mut c = client.borrow_mut();
        pipeline.infer(&mut c, &mut world.net, tokens.clone()).unwrap();
    }
    let deadline = world.net.now() + 120 * SECOND;
    while pipeline.completed.len() < requests && world.net.now() < deadline {
        world.run_for(SECOND / 50);
        let evs = client.borrow_mut().drain_events();
        let mut c = client.borrow_mut();
        for e in &evs {
            if let NodeEvent::Rpc(ev) = e {
                pipeline.on_rpc_event(&mut c, &mut world.net, ev);
            }
        }
        pipeline.tick(&mut c, &mut world.net);
    }

    println!(
        "healthy phase: {healthy_done} requests in {healthy_virt:.2}s virtual ({:.1} req/s)",
        healthy_done as f64 / healthy_virt
    );
    println!(
        "failover phase: {} total completed, {} failed (wall {:?})",
        pipeline.completed.len(),
        pipeline.failed.len(),
        wall.elapsed()
    );
    // Logits sanity: finite values of vocab size.
    let (_, logits, _) = &pipeline.completed[0];
    assert_eq!(logits.shape, vec![1, cfg.vocab]);
    assert!(logits.as_f32().unwrap().iter().all(|v| v.is_finite()));
    assert_eq!(pipeline.completed.len(), requests, "all requests must finish");
    assert!(
        pipeline.failed.is_empty(),
        "failover must mask the dead replica"
    );
    println!("shape check OK: shard failure masked by DHT/stub failover");
}
