//! Fig. 1(3): RL pipeline — the training cluster publishes checkpoint
//! versions; inference replicas synchronize. Four arms compare
//! {parameter-server vs swarm} × {full re-pull vs delta}:
//!
//! - `central/full`: every replica pulls the whole blob from the trainer
//!   each version (the classic parameter-server worst case).
//! - `central/delta`: replicas keep the previous version's chunks, so
//!   content addressing already skips unchanged chunks — but all traffic
//!   still originates at the trainer.
//! - `swarm/full` and `swarm/delta`: replicas announce themselves as
//!   seeders mid-download, discover each other via the DHT and the
//!   connected-mesh overlay, and the choked publisher's egress stays
//!   ~O(1) in the replica count.
//!
//! Reports per-version trainer egress, p50/p99 replica sync latency and
//! the fraction of full demand actually moved (the delta evidence), and
//! asserts the headline: swarm-delta beats central-full on BOTH trainer
//! egress and p99 sync latency.
//!
//! A second A/B pair isolates the control plane on a 10k-chunk sync
//! (256 B fixed chunks): `control/legacy` runs with compact addressing,
//! HAVE batching and gossip lazy push disabled; `control/compact` with
//! them on. Both rows emit `control_bytes` and `control_ratio`
//! (bytes-of-control-per-delivered-byte), and the compressed arm must
//! cut the ratio at least 5x.

use lattica::scenarios::{model_sync_scenario, ModelSyncConfig, SyncMode};
use lattica::util::cli::Args;
use lattica::util::json::Json;
use lattica::util::timefmt;

fn main() {
    let args = Args::from_env();
    let checkpoints = args.opt_usize("checkpoints", 3).unwrap();
    let replicas = args.opt_usize("replicas", 8).unwrap();
    let blob_bytes = args.opt_usize("blob-kb", 3 * 1024).unwrap() * 1024;

    println!(
        "Fig 1(3): model sync — {} blob, {replicas} replicas, {checkpoints} checkpoints, ~10% churn/version",
        timefmt::fmt_bytes(blob_bytes as u64)
    );

    let arms: [(&str, SyncMode, bool); 4] = [
        ("central/full", SyncMode::Central, false),
        ("central/delta", SyncMode::Central, true),
        ("swarm/full", SyncMode::Swarm, false),
        ("swarm/delta", SyncMode::Swarm, true),
    ];
    let mut rows: Vec<Json> = Vec::new();
    // (egress per ckpt, p99 secs) for the headline comparison.
    let mut headline: Vec<(f64, f64)> = Vec::new();
    for (label, mode, delta) in arms {
        let wall_start = std::time::Instant::now();
        let mut out = model_sync_scenario(&ModelSyncConfig {
            replicas,
            checkpoints,
            blob_bytes,
            churn: 0.10,
            mode,
            delta,
            nat_mixed: false,
            chunk_bytes: 0,
            compact_control: true,
            seed: 61,
            timeout_secs: 240,
        });
        assert!(out.completed, "[{label}] sync did not complete");
        assert!(out.all_identical, "[{label}] replicas diverged");
        let p50 = out.stats.latency.percentile(50.0) as f64 / 1e9;
        let p99 = out.stats.latency.percentile(99.0) as f64 / 1e9;
        let egress = out.stats.mean_egress();
        let frac_v2 = if checkpoints > 1 { out.stats.fetched_fraction(1) } else { 1.0 };
        println!(
            "  [{label:<13}] egress/ckpt {} ({:.2}x blob max), sync p50 {p50:.2}s p99 {p99:.2}s, v2 moved {:.0}% of full demand",
            timefmt::fmt_bytes(egress as u64),
            out.stats.max_egress_x_blob(),
            frac_v2 * 100.0
        );
        headline.push((egress, p99));
        rows.push(Json::obj(vec![
            ("mode", Json::str(match mode {
                SyncMode::Central => "central",
                SyncMode::Swarm => "swarm",
            })),
            ("delta", Json::Bool(delta)),
            ("replicas", Json::num(replicas as f64)),
            ("checkpoints", Json::num(checkpoints as f64)),
            ("blob_bytes", Json::num(blob_bytes as f64)),
            ("trainer_egress_per_ckpt", Json::num(egress)),
            ("max_egress_x_blob", Json::num(out.stats.max_egress_x_blob())),
            ("sync_p50_secs", Json::num(p50)),
            ("sync_p99_secs", Json::num(p99)),
            ("fetched_fraction_v2", Json::num(frac_v2)),
            ("duplicate_blocks", Json::num(out.duplicate_blocks as f64)),
            (
                "replica_bytes_served",
                Json::num(out.replica_bytes_served as f64),
            ),
            ("wall_secs", Json::num(wall_start.elapsed().as_secs_f64())),
            ("control_bytes", Json::num(out.control.control_bytes() as f64)),
            ("control_ratio", Json::num(out.control.ratio())),
        ]));
    }

    // Control-plane A/B: same swarm/delta topology, 10k fixed-size chunks
    // so per-chunk metadata dominates, legacy vs compact control plane.
    let control_arms: [(&str, bool); 2] = [("control/legacy", false), ("control/compact", true)];
    let mut control_ratios: Vec<f64> = Vec::new();
    for (label, compact) in control_arms {
        let wall_start = std::time::Instant::now();
        let out = model_sync_scenario(&ModelSyncConfig {
            replicas: 3,
            checkpoints: 1,
            blob_bytes: 2_560_000,
            churn: 0.0,
            mode: SyncMode::Swarm,
            delta: true,
            nat_mixed: false,
            chunk_bytes: 256,
            compact_control: compact,
            seed: 71,
            timeout_secs: 240,
        });
        assert!(out.completed, "[{label}] sync did not complete");
        assert!(out.all_identical, "[{label}] replicas diverged");
        let ratio = out.control.ratio();
        assert!(ratio > 0.0, "[{label}] control ratio must be nonzero");
        println!("  [{label:<15}] {}", out.control.summary());
        control_ratios.push(ratio);
        rows.push(Json::obj(vec![
            ("mode", Json::str("swarm")),
            ("delta", Json::Bool(true)),
            ("compact_control", Json::Bool(compact)),
            ("replicas", Json::num(3.0)),
            ("checkpoints", Json::num(1.0)),
            ("blob_bytes", Json::num(2_560_000.0)),
            ("chunk_bytes", Json::num(256.0)),
            ("control_bytes", Json::num(out.control.control_bytes() as f64)),
            ("control_ratio", Json::num(ratio)),
            (
                "delivered_bytes",
                Json::num(out.control.delivered_bytes as f64),
            ),
            ("wall_secs", Json::num(wall_start.elapsed().as_secs_f64())),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("model_sync")),
        ("blob_bytes", Json::num(blob_bytes as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_model_sync.json");
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    // Headline: swarm-delta must beat central-full on both axes.
    let (central_full_egress, central_full_p99) = headline[0];
    let (swarm_delta_egress, swarm_delta_p99) = headline[3];
    assert!(
        swarm_delta_egress < central_full_egress,
        "swarm-delta egress {swarm_delta_egress} must beat central-full {central_full_egress}"
    );
    assert!(
        swarm_delta_p99 < central_full_p99,
        "swarm-delta p99 {swarm_delta_p99}s must beat central-full {central_full_p99}s"
    );
    // Control-plane headline: compressed control plane must cut the
    // bytes-of-control-per-delivered-byte ratio at least 5x on the
    // 10k-chunk sync.
    let (legacy_ratio, compact_ratio) = (control_ratios[0], control_ratios[1]);
    assert!(
        legacy_ratio >= 5.0 * compact_ratio,
        "compact control plane must cut control ratio >=5x (legacy {legacy_ratio:.4} vs compact {compact_ratio:.4})"
    );
    println!(
        "shape check OK: swarm-delta beats parameter-server-full on egress and p99; \
         compact control plane cuts control ratio {:.1}x ({legacy_ratio:.4} -> {compact_ratio:.4})",
        legacy_ratio / compact_ratio
    );
}
