//! Fig. 1(3): RL pipeline — the training cluster publishes model chunks;
//! inference clusters A–C synchronize via gossip announcements + Bitswap,
//! compared against a central parameter-server baseline (every cluster
//! pulls the full blob from the trainer).
//!
//! Reports per-checkpoint sync latency and trainer egress. The model blob
//! is the real parameter set from `artifacts/` when present (run
//! `make artifacts`), or a synthetic 3.5 MB blob otherwise.

use lattica::content::DagManifest;
use lattica::netsim::link::PathProfile;
use lattica::netsim::topology::LinkProfile;
use lattica::netsim::{MILLI, SECOND};
use lattica::node::{run_until, NodeEvent};
use lattica::protocols::gossip::GossipEvent;
use lattica::scenarios::bootstrap_mesh_on;
use lattica::util::cli::Args;
use lattica::util::json::Json;
use lattica::util::timefmt;

fn main() {
    let args = Args::from_env();
    let checkpoints = args.opt_usize("checkpoints", 3).unwrap();
    let clusters = args.opt_usize("clusters", 3).unwrap();

    // Model blob: real init params if available.
    let blob: Vec<u8> = {
        let p = std::path::Path::new("artifacts/init_params.bin");
        if p.exists() {
            std::fs::read(p).unwrap()
        } else {
            let mut rng = lattica::util::Rng::new(5);
            rng.gen_bytes(3_500_000)
        }
    };
    println!(
        "Fig 1(3): model sync — {} checkpoint blob, {clusters} inference clusters",
        timefmt::fmt_bytes(blob.len() as u64)
    );

    // Network scenarios: the clean 1 Gbps mesh, and the same mesh across
    // a lossy 75 ms WAN (what the CC subsystem + RACK recovery is for).
    let lossy = Some(PathProfile::new(75 * MILLI, 3 * MILLI, 0.02));
    let runs: [(&str, Option<PathProfile>, bool); 4] = [
        ("lan", None, true),
        ("lan", None, false),
        ("lossy_wan", lossy, true),
        ("lossy_wan", lossy, false),
    ];
    let mut json_rows: Vec<Json> = Vec::new();
    for (scenario, path, p2p) in runs {
        let wall_start = std::time::Instant::now();
        let (mut world, nodes) =
            bootstrap_mesh_on(clusters + 1, if p2p { 41 } else { 42 }, LinkProfile::FIBER, path);
        let trainer = nodes[0].clone();
        let trainer_peer = trainer.borrow().peer_id();
        // Everyone subscribes to the model topic.
        for nd in &nodes {
            let mut n = nd.borrow_mut();
            let lattica::node::LatticaNode { swarm, gossip, .. } = &mut *n;
            let mut ctx = lattica::protocols::Ctx::new(swarm, &mut world.net);
            gossip.subscribe(&mut ctx, &lattica::model::model_topic("policy"));
        }
        world.run_for(SECOND);

        let mut sync_times = Vec::new();
        for v in 1..=checkpoints {
            // Trainer publishes checkpoint v (content + DHT + gossip).
            let t0 = world.net.now();
            let root = {
                let mut tr = trainer.borrow_mut();
                // Vary the blob per version so chunks differ.
                let mut data = blob.clone();
                data[0] = v as u8;
                let root = tr.publish_blob(&mut world.net, "policy-blob", v as u64, &data, 256 * 1024);
                // Gossip the announcement (what publish_checkpoint does for
                // real tensor checkpoints — see examples/collaborative_rl).
                let ann = lattica::model::ModelAnnouncement {
                    name: "policy".into(),
                    version: v as u64,
                    root,
                };
                let lattica::node::LatticaNode { swarm, gossip, .. } = &mut *tr;
                let mut ctx = lattica::protocols::Ctx::new(swarm, &mut world.net);
                gossip.publish(&mut ctx, &lattica::model::model_topic("policy"), ann.encode());
                root
            };
            world.run_for(SECOND / 2);
            // Clusters hear the announcement (or poll, in the baseline) and fetch.
            for c in &nodes[1..] {
                // Drain gossip to emulate reacting to the announcement.
                let _ann = c
                    .borrow_mut()
                    .drain_events()
                    .into_iter()
                    .filter_map(|e| match e {
                        NodeEvent::Gossip(GossipEvent::Received { data, .. }) => Some(data),
                        _ => None,
                    })
                    .last();
                let providers = if p2p {
                    nodes.iter().map(|n| n.borrow().peer_id()).collect()
                } else {
                    vec![trainer_peer]
                };
                c.borrow_mut().fetch_blob(&mut world.net, root, vec![trainer_peer]);
                let _ = providers;
            }
            let manifest_timeout = if path.is_some() { 120 * SECOND } else { 30 * SECOND };
            run_until(&mut world, manifest_timeout, || {
                nodes[1..].iter().all(|c| c.borrow().blockstore.has(&root))
            });
            for c in &nodes[1..] {
                let providers: Vec<_> = if p2p {
                    nodes.iter().map(|n| n.borrow().peer_id()).collect()
                } else {
                    vec![trainer_peer]
                };
                c.borrow_mut()
                    .fetch_manifest_chunks(&mut world.net, &root, providers)
                    .unwrap();
            }
            let chunk_timeout = if path.is_some() { 600 * SECOND } else { 120 * SECOND };
            let ok = run_until(&mut world, chunk_timeout, || {
                nodes[1..].iter().all(|c| {
                    let n = c.borrow();
                    DagManifest::load(&n.blockstore, &root)
                        .map(|m| m.is_complete(&n.blockstore))
                        .unwrap_or(false)
                })
            });
            assert!(ok, "checkpoint {v} did not propagate");
            sync_times.push((world.net.now() - t0) as f64 / 1e9);
        }
        let egress: u64 = trainer
            .borrow()
            .bitswap
            .ledgers
            .values()
            .map(|l| l.bytes_sent)
            .sum();
        let mean = sync_times.iter().sum::<f64>() / sync_times.len() as f64;
        let health = trainer.borrow().swarm.transport_health();
        println!(
            "  [{scenario}] {}: mean sync {mean:.2}s/checkpoint, trainer egress {}, retx {}",
            if p2p { "lattica p2p   " } else { "central server" },
            timefmt::fmt_bytes(egress),
            timefmt::fmt_bytes(health.bytes_retransmitted)
        );
        json_rows.push(Json::obj(vec![
            ("scenario", Json::str(scenario)),
            ("mode", Json::str(if p2p { "p2p" } else { "central" })),
            ("mean_sync_secs", Json::num(mean)),
            ("trainer_egress_bytes", Json::num(egress as f64)),
            ("checkpoints", Json::num(checkpoints as f64)),
            ("clusters", Json::num(clusters as f64)),
            ("wall_secs", Json::num(wall_start.elapsed().as_secs_f64())),
            ("cwnd", Json::num(health.mean_cwnd() as f64)),
            ("srtt_ns", Json::num(health.mean_srtt() as f64)),
            ("retx_bytes", Json::num(health.bytes_retransmitted as f64)),
            ("loss_events", Json::num(health.loss_events as f64)),
            ("pacer_utilization", Json::num(health.mean_pacer_utilization())),
        ]));
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("model_sync")),
        ("blob_bytes", Json::num(blob.len() as f64)),
        ("rows", Json::Arr(json_rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_model_sync.json");
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    println!("done (lower trainer egress in p2p mode = the decentralized-CDN effect)");
}
