//! §4 + Fig. 1(1): NAT traversal success.
//!
//! Samples peer pairs from a measured NAT-type distribution, runs the full
//! relay + reserve + DCUtR pipeline, and reports the direct-connection
//! success rate (paper: ~70 %) plus 100 % reachability including relay
//! fallback. `--matrix` prints the per-NAT-pair outcome matrix and
//! compares it to the Ford et al. oracle.

use lattica::multiaddr::Multiaddr;
use lattica::netsim::nat::NatType;
use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::{run_until, LatticaNode, NodeConfig};
use lattica::protocols::Ctx;
use lattica::scenarios::{oracle_pair_success, sample_nat};
use lattica::swarm::Path;
use lattica::util::cli::Args;
use lattica::util::Rng;

/// One traversal attempt between two sampled NAT types.
/// Returns (connected_at_all, direct).
fn attempt(a_nat: Option<NatType>, b_nat: Option<NatType>, seed: u64) -> (bool, bool) {
    let mut t = TopologyBuilder::paper_regions();
    let hr = t.public_host(0, LinkProfile::DATACENTER);
    let mk = |t: &mut TopologyBuilder, nat: Option<NatType>, region| match nat {
        None => t.public_host(region, LinkProfile::FIBER),
        Some(n) => {
            let id = t.nat(region, n, LinkProfile::FIBER);
            t.natted_host(id, LinkProfile::UNLIMITED)
        }
    };
    let ha = mk(&mut t, a_nat, 1);
    let hb = mk(&mut t, b_nat, 2);
    let mut world = World::new(t.build(seed));
    let relay = LatticaNode::spawn(&mut world, hr, NodeConfig::relay(seed * 7 + 1));
    let a = LatticaNode::spawn(&mut world, ha, NodeConfig::with_seed(seed * 7 + 2));
    let b = LatticaNode::spawn(&mut world, hb, NodeConfig::with_seed(seed * 7 + 3));
    let relay_ma = relay.borrow().listen_addr();
    let relay_peer = relay.borrow().peer_id();
    let b_peer = b.borrow().peer_id();

    a.borrow_mut().dial(&mut world.net, &relay_ma).unwrap();
    b.borrow_mut().dial(&mut world.net, &relay_ma).unwrap();
    world.run_for(SECOND);
    let _ = a.borrow_mut().swarm.relay_reserve(&mut world.net, &relay_peer);
    let _ = b.borrow_mut().swarm.relay_reserve(&mut world.net, &relay_peer);
    world.run_for(SECOND);

    // If B is public, A can dial it directly (no punch needed).
    if b_nat.is_none() {
        let ma = b.borrow().listen_addr();
        a.borrow_mut().dial(&mut world.net, &ma).unwrap();
        let ok = run_until(&mut world, 5 * SECOND, || a.borrow().swarm.is_connected(&b_peer));
        return (ok, ok);
    }

    // Circuit dial, then DCUtR upgrade.
    let circuit = Multiaddr::circuit(relay_ma.clone(), b_peer);
    a.borrow_mut().dial(&mut world.net, &circuit).unwrap();
    let relayed_ok = run_until(&mut world, 8 * SECOND, || a.borrow().swarm.is_connected(&b_peer));
    if !relayed_ok {
        return (false, false);
    }
    // DCUtR over the relayed connection.
    let cid = a.borrow().swarm.conns_to(&b_peer)[0];
    {
        let mut n = a.borrow_mut();
        let LatticaNode { swarm, dcutr, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        let _ = dcutr.upgrade(&mut ctx, cid, &b_peer);
    }
    world.run_for(5 * SECOND);
    let direct = {
        let n = a.borrow();
        n.swarm
            .conns_to(&b_peer)
            .iter()
            .any(|c| matches!(n.swarm.connection_path(*c), Some(Path::Direct(_))))
    };
    (true, direct)
}

fn label(n: Option<NatType>) -> &'static str {
    match n {
        None => "public",
        Some(t) => t.label(),
    }
}

fn main() {
    let args = Args::from_env();
    let pairs = args.opt_usize("pairs", 80).unwrap();
    let matrix = args.flag("matrix");

    if matrix {
        // Fig. 1(1): per-NAT-pair traversal matrix vs the Ford oracle.
        let kinds = [
            None,
            Some(NatType::FullCone),
            Some(NatType::RestrictedCone),
            Some(NatType::PortRestrictedCone),
            Some(NatType::Symmetric),
        ];
        println!("Fig 1(1): direct-upgrade outcome per NAT pairing (measured / oracle)");
        print!("{:<16}", "");
        for b in kinds {
            print!("{:<18}", label(b));
        }
        println!();
        let mut disagreements = 0;
        for (i, a) in kinds.iter().enumerate() {
            print!("{:<16}", label(*a));
            for (j, b) in kinds.iter().enumerate() {
                let (reach, direct) = attempt(*a, *b, 1000 + (i * 8 + j) as u64);
                let oracle = oracle_pair_success(*a, *b);
                if direct != oracle {
                    disagreements += 1;
                }
                print!(
                    "{:<18}",
                    format!(
                        "{}{} / {}",
                        if direct { "direct" } else { "relay " },
                        if reach { "" } else { "!" },
                        if oracle { "direct" } else { "relay" }
                    )
                );
            }
            println!();
        }
        println!("\ndisagreements with oracle: {disagreements}/25");
        assert!(disagreements <= 2, "traversal matrix diverges from Ford oracle");
        return;
    }

    // §4 headline: sampled-pair success rate.
    let mut rng = Rng::new(2025);
    let mut reached = 0usize;
    let mut direct = 0usize;
    let mut oracle_direct = 0usize;
    for i in 0..pairs {
        let a = sample_nat(&mut rng);
        let b = sample_nat(&mut rng);
        let (r, d) = attempt(a, b, 5000 + i as u64);
        reached += r as usize;
        direct += d as usize;
        oracle_direct += oracle_pair_success(a, b) as usize;
    }
    let direct_rate = direct as f64 / pairs as f64 * 100.0;
    let reach_rate = reached as f64 / pairs as f64 * 100.0;
    let oracle_rate = oracle_direct as f64 / pairs as f64 * 100.0;
    println!("NAT traversal over {pairs} sampled peer pairs:");
    println!("  direct connections:   {direct_rate:.1}%   (paper: ~70%)");
    println!("  oracle expectation:   {oracle_rate:.1}%   (Ford et al. matrix over the NAT mix)");
    println!("  total reachability:   {reach_rate:.1}%   (paper: 100% via relay fallback)");
    assert!(
        (55.0..=85.0).contains(&direct_rate),
        "direct rate {direct_rate}% outside the paper's band"
    );
    assert!(reach_rate >= 99.0, "relay fallback must reach everyone");
    println!("shape check OK");
}
