//! §4 + Fig. 1(1): NAT traversal success — emits `BENCH_nat_traversal.json`.
//!
//! Three arms, all deterministic:
//!
//! 1. **Measured punch matrix** (`netsim::nat::measure_punch_matrix`): the
//!    realistic-NAT lab harness (misbehaving boxes, mapping-timeout races,
//!    birthday-paradox port spray against sequential symmetric NATs) per
//!    unordered NAT-type pair, asserted against the calibration bands
//!    from the Trautwein et al. measurement study.
//! 2. **Mixed-NAT mesh** (`scenarios::nat_mesh`): nodes behind sampled
//!    NAT types bootstrap, AutoNAT-classify themselves, reserve on the
//!    least-loaded advertised relays, then sampled pairs connect (direct
//!    dial / circuit + DCUtR). Acceptance: ≥95 % pairwise connectivity
//!    with bounded per-relay egress. Default is the 1 k-node arm;
//!    `--quick` runs the small one.
//! 3. **Relay-kill failover**: a circuit's relay dies unclean mid-stream;
//!    the logical connection must re-home to a backup relay without a
//!    disconnect and still carry RPCs.
//!
//! The legacy node-pipeline headline (sampled pairs through the full
//! relay + reserve + DCUtR flow vs the Ford oracle, paper: ~70 % direct)
//! is kept as a fourth section.

use lattica::multiaddr::Multiaddr;
use lattica::netsim::nat::{measure_punch_matrix, punch_success_band, NatType};
use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::{run_until, LatticaNode, NodeConfig};
use lattica::protocols::Ctx;
use lattica::scenarios::{nat_mesh, oracle_pair_success, sample_nat, NatMeshConfig};
use lattica::swarm::Path;
use lattica::util::cli::Args;
use lattica::util::json::Json;
use lattica::util::Rng;

/// One traversal attempt between two sampled NAT types through the full
/// node pipeline (legacy Ford-faithful boxes: the clean-theory headline).
/// Returns (connected_at_all, direct).
fn attempt(a_nat: Option<NatType>, b_nat: Option<NatType>, seed: u64) -> (bool, bool) {
    let mut t = TopologyBuilder::paper_regions();
    let hr = t.public_host(0, LinkProfile::DATACENTER);
    let mk = |t: &mut TopologyBuilder, nat: Option<NatType>, region| match nat {
        None => t.public_host(region, LinkProfile::FIBER),
        Some(n) => {
            let id = t.nat(region, n, LinkProfile::FIBER);
            t.natted_host(id, LinkProfile::UNLIMITED)
        }
    };
    let ha = mk(&mut t, a_nat, 1);
    let hb = mk(&mut t, b_nat, 2);
    let mut world = World::new(t.build(seed));
    let relay = LatticaNode::spawn(&mut world, hr, NodeConfig::relay(seed * 7 + 1));
    let a = LatticaNode::spawn(&mut world, ha, NodeConfig::with_seed(seed * 7 + 2));
    let b = LatticaNode::spawn(&mut world, hb, NodeConfig::with_seed(seed * 7 + 3));
    let relay_ma = relay.borrow().listen_addr();
    let relay_peer = relay.borrow().peer_id();
    let b_peer = b.borrow().peer_id();

    a.borrow_mut().dial(&mut world.net, &relay_ma).unwrap();
    b.borrow_mut().dial(&mut world.net, &relay_ma).unwrap();
    world.run_for(SECOND);
    let _ = a.borrow_mut().swarm.relay_reserve(&mut world.net, &relay_peer);
    let _ = b.borrow_mut().swarm.relay_reserve(&mut world.net, &relay_peer);
    world.run_for(SECOND);

    // If B is public, A can dial it directly (no punch needed).
    if b_nat.is_none() {
        let ma = b.borrow().listen_addr();
        a.borrow_mut().dial(&mut world.net, &ma).unwrap();
        let ok = run_until(&mut world, 5 * SECOND, || a.borrow().swarm.is_connected(&b_peer));
        return (ok, ok);
    }

    // Circuit dial, then DCUtR upgrade.
    let circuit = Multiaddr::circuit(relay_ma.clone(), b_peer);
    a.borrow_mut().dial(&mut world.net, &circuit).unwrap();
    let relayed_ok = run_until(&mut world, 8 * SECOND, || a.borrow().swarm.is_connected(&b_peer));
    if !relayed_ok {
        return (false, false);
    }
    // DCUtR over the relayed connection.
    let cid = a.borrow().swarm.conns_to(&b_peer)[0];
    {
        let mut n = a.borrow_mut();
        let LatticaNode { swarm, dcutr, .. } = &mut *n;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        let _ = dcutr.upgrade(&mut ctx, cid, &b_peer);
    }
    world.run_for(5 * SECOND);
    let direct = {
        let n = a.borrow();
        n.swarm
            .conns_to(&b_peer)
            .iter()
            .any(|c| matches!(n.swarm.connection_path(*c), Some(Path::Direct(_))))
    };
    (true, direct)
}

fn main() {
    let args = Args::from_env();
    let pairs = args.opt_usize("pairs", 40).unwrap();
    let trials = args.opt_usize("trials", 250).unwrap() as u32;
    let quick = args.flag("quick");
    let seed = args.opt_usize("seed", 42).unwrap() as u64;

    // --- 1. Measured punch matrix vs calibration bands ---------------------
    // Sampling slack on top of the configured band: ~3σ at 250 trials.
    let slack = (0.25 / (trials as f64).sqrt() * 3.0).max(0.06);
    println!("Measured punch matrix ({trials} trials/pair, spray 16):");
    let matrix = measure_punch_matrix(trials, 16, seed);
    let mut matrix_rows: Vec<Json> = Vec::new();
    for &(a, b, rate) in &matrix {
        let (lo, hi) = punch_success_band(a, b);
        let ok = rate >= lo - slack && rate <= hi + slack;
        println!(
            "  {:<16} x {:<16} {:>5.1}%   band [{:.0}%, {:.0}%] {}",
            a.label(),
            b.label(),
            rate * 100.0,
            lo * 100.0,
            hi * 100.0,
            if ok { "" } else { "  <-- OUT OF BAND" }
        );
        matrix_rows.push(Json::obj(vec![
            ("pair", Json::str(&format!("{}|{}", a.label(), b.label()))),
            ("measured", Json::num(rate)),
            ("band_lo", Json::num(lo)),
            ("band_hi", Json::num(hi)),
        ]));
        assert!(
            ok,
            "punch rate {:.3} for {}|{} outside band [{lo}, {hi}] (slack {slack:.3})",
            rate,
            a.label(),
            b.label()
        );
    }

    // --- 2. Mixed-NAT mesh --------------------------------------------------
    let mcfg = if quick { NatMeshConfig::quick(seed) } else { NatMeshConfig::ci(seed) };
    println!(
        "\nMixed-NAT mesh: {} nodes, {} seed relays, {} sampled pairs",
        mcfg.nodes, mcfg.relays, mcfg.pair_samples
    );
    let mesh = nat_mesh(&mcfg);
    println!(
        "  connectivity {:.1}%  ({} of {} pairs; {} direct)",
        mesh.connectivity * 100.0,
        mesh.connected,
        mesh.attempted,
        mesh.direct
    );
    println!(
        "  reservation coverage {:.1}%, {} self-promoted relays",
        mesh.reservation_coverage * 100.0,
        mesh.promoted
    );
    for r in &mesh.relay_rows {
        println!(
            "  {:<20} {:>10} B relayed  {:>4} circuits ({} refused)  util {:>3}  avg {} B/s",
            r.label, r.bytes_relayed, r.circuits_opened, r.circuits_refused, r.utilization,
            r.egress_bps_avg
        );
    }
    let mesh_pair_rows: Vec<Json> = mesh
        .pair_rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("pair", Json::str(&r.label)),
                ("attempted", Json::num(r.attempted as f64)),
                ("connected", Json::num(r.connected as f64)),
                ("direct", Json::num(r.direct as f64)),
                ("relayed", Json::num(r.relayed as f64)),
            ])
        })
        .collect();
    let mesh_relay_rows: Vec<Json> = mesh
        .relay_rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("relay", Json::str(&r.label)),
                ("bytes_relayed", Json::num(r.bytes_relayed as f64)),
                ("circuits_opened", Json::num(r.circuits_opened as f64)),
                ("circuits_refused", Json::num(r.circuits_refused as f64)),
                ("reservations_refused", Json::num(r.reservations_refused as f64)),
                ("utilization", Json::num(r.utilization as f64)),
                ("egress_bps_avg", Json::num(r.egress_bps_avg as f64)),
            ])
        })
        .collect();

    // --- 3. Relay-kill mid-stream failover ----------------------------------
    let mut kcfg = NatMeshConfig::quick(seed + 1);
    kcfg.relay_kill = true;
    kcfg.pair_samples = 8;
    println!("\nRelay-kill failover arm ({} nodes, {} relays):", kcfg.nodes, kcfg.relays);
    let kill = nat_mesh(&kcfg);
    let failover_json = match &kill.failover {
        Some(f) => {
            println!(
                "  recovered={} post-kill-rpc={} disconnect-surfaced={} (completed failovers: {})",
                f.recovered, f.call_after_kill_ok, f.peer_disconnected_seen, f.failovers_completed
            );
            Json::obj(vec![
                ("recovered", Json::Bool(f.recovered)),
                ("call_after_kill_ok", Json::Bool(f.call_after_kill_ok)),
                ("peer_disconnected_seen", Json::Bool(f.peer_disconnected_seen)),
                ("failovers_completed", Json::num(f.failovers_completed as f64)),
            ])
        }
        None => {
            println!("  no eligible shared-reservation pair found");
            Json::Null
        }
    };

    // --- 4. Legacy node-pipeline headline (Ford-faithful boxes) -------------
    let mut rng = Rng::new(2025);
    let mut reached = 0usize;
    let mut direct = 0usize;
    let mut oracle_direct = 0usize;
    for i in 0..pairs {
        let a = sample_nat(&mut rng);
        let b = sample_nat(&mut rng);
        let (r, d) = attempt(a, b, 5000 + i as u64);
        reached += r as usize;
        direct += d as usize;
        oracle_direct += oracle_pair_success(a, b) as usize;
    }
    let direct_rate = direct as f64 / pairs as f64 * 100.0;
    let reach_rate = reached as f64 / pairs as f64 * 100.0;
    let oracle_rate = oracle_direct as f64 / pairs as f64 * 100.0;
    println!("\nNode pipeline over {pairs} sampled peer pairs (idealised boxes):");
    println!("  direct connections:   {direct_rate:.1}%   (paper: ~70%)");
    println!("  oracle expectation:   {oracle_rate:.1}%   (Ford et al. matrix over the NAT mix)");
    println!("  total reachability:   {reach_rate:.1}%   (paper: 100% via relay fallback)");

    // --- Emit ---------------------------------------------------------------
    let doc = Json::obj(vec![
        ("bench", Json::str("nat_traversal")),
        ("trials_per_pair", Json::num(trials as f64)),
        ("rows", Json::Arr(matrix_rows)),
        (
            "mesh",
            Json::obj(vec![
                ("nodes", Json::num(mesh.nodes as f64)),
                ("relays", Json::num(mesh.relays as f64)),
                ("attempted", Json::num(mesh.attempted as f64)),
                ("connected", Json::num(mesh.connected as f64)),
                ("direct", Json::num(mesh.direct as f64)),
                ("connectivity", Json::num(mesh.connectivity)),
                ("reservation_coverage", Json::num(mesh.reservation_coverage)),
                ("promoted", Json::num(mesh.promoted as f64)),
                ("pair_rows", Json::Arr(mesh_pair_rows)),
                ("relay_rows", Json::Arr(mesh_relay_rows)),
            ]),
        ),
        ("failover", failover_json),
        (
            "pipeline",
            Json::obj(vec![
                ("pairs", Json::num(pairs as f64)),
                ("direct_rate", Json::num(direct_rate)),
                ("oracle_rate", Json::num(oracle_rate)),
                ("reach_rate", Json::num(reach_rate)),
            ]),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_nat_traversal.json");
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // --- Shape checks (after the JSON lands, so failures still publish) -----
    assert!(
        mesh.connectivity >= 0.95,
        "mixed-NAT mesh connectivity {:.3} below the 95% acceptance bar",
        mesh.connectivity
    );
    if mcfg.relay_egress_bps > 0 {
        for r in &mesh.relay_rows {
            assert!(
                r.egress_bps_avg <= mcfg.relay_egress_bps,
                "relay {} average egress {} B/s exceeds the {} B/s budget",
                r.label,
                r.egress_bps_avg,
                mcfg.relay_egress_bps
            );
        }
    }
    if let Some(f) = &kill.failover {
        assert!(f.recovered, "mid-stream relay failover did not recover");
        assert!(f.call_after_kill_ok, "post-failover RPC failed");
        assert!(!f.peer_disconnected_seen, "failover surfaced a disconnect");
    }
    assert!(
        (55.0..=85.0).contains(&direct_rate),
        "direct rate {direct_rate}% outside the paper's band"
    );
    assert!(reach_rate >= 99.0, "relay fallback must reach everyone");
    println!("shape checks OK");
}
