//! DHT scaling and churn resilience.
//!
//! Phase 1 — lookup hops/latency vs network size (the architecture's
//! O(log N) claim, §2). Phase 2 — the `bootstrap_mesh` churn scenario:
//! nodes join/leave/crash on a seeded Poisson schedule (median session
//! half-life 60 s virtual) while `get_providers` lookups for live content
//! run continuously; success-rate / hop-count / staleness land in
//! `BENCH_dht_churn.json`.

use lattica::metrics::Histogram;
use lattica::netsim::topology::LinkProfile;
use lattica::netsim::SECOND;
use lattica::node::{run_until, LatticaNode, NodeEvent};
use lattica::protocols::kad::KadEvent;
use lattica::protocols::Ctx;
use lattica::scenarios::{
    bootstrap_mesh, churn_scenario, planet_scale, ChurnLookupOutcome, PlanetConfig,
    PlanetOutcome,
};
use lattica::util::cli::Args;
use lattica::util::json::Json;
use lattica::util::Rng;

fn run(n: usize, lookups: usize, seed: u64) -> (f64, Histogram) {
    let (mut world, nodes) = bootstrap_mesh(n, seed, LinkProfile::DATACENTER);
    // Let the mesh settle + everyone self-lookup happened in bootstrap.
    world.run_for(3 * SECOND);
    let mut rng = Rng::new(seed ^ 0xD47);
    let mut hops_total = 0u64;
    let mut finished = 0usize;
    let mut lat = Histogram::new();
    for _ in 0..lookups {
        let src = rng.gen_index(n);
        let dst = rng.gen_index(n);
        let target = *nodes[dst].borrow().peer_id().as_bytes();
        // Clear any leftover events from previous lookups.
        let _ = nodes[src].borrow_mut().drain_events();
        let t0 = world.net.now();
        let qid = {
            let mut nd = nodes[src].borrow_mut();
            let LatticaNode { swarm, kad, .. } = &mut *nd;
            let mut ctx = Ctx::new(swarm, &mut world.net);
            kad.find_node(&mut ctx, target)
        };
        let mut hops = None;
        run_until(&mut world, 20 * SECOND, || {
            if hops.is_none() {
                let mut nd = nodes[src].borrow_mut();
                for e in nd.drain_events() {
                    // Match the query id: maintenance refresh lookups also
                    // emit QueryFinished and must not pollute the sample.
                    if let NodeEvent::Kad(KadEvent::QueryFinished { query_id, hops: h, .. }) = e {
                        if query_id == qid {
                            hops = Some(h);
                        }
                    }
                }
            }
            hops.is_some()
        });
        if let Some(h) = hops {
            hops_total += h as u64;
            finished += 1;
            lat.record(world.net.now() - t0);
        }
    }
    (hops_total as f64 / finished.max(1) as f64, lat)
}

/// One churn arm over the canonical shared scenario (the same harness
/// the acceptance test gates on). `half_life == 0` disables churn.
fn churn_arm(n: usize, half_life: u64, seed: u64) -> ChurnLookupOutcome {
    churn_scenario(n, half_life, 90, seed)
}

fn arm_row(label: &str, n: usize, half_life: u64, o: &mut ChurnLookupOutcome) -> Json {
    Json::obj(vec![
        ("arm", Json::str(label)),
        ("nodes", Json::num(n as f64)),
        ("session_half_life_secs", Json::num(half_life as f64)),
        ("lookups", Json::num(o.stats.attempted as f64)),
        ("aborted", Json::num(o.stats.aborted as f64)),
        ("success_rate", Json::num(o.stats.success_rate())),
        ("mean_hops", Json::num(o.stats.mean_hops())),
        ("p95_hops", Json::num(o.stats.hops.percentile(95.0) as f64)),
        ("p95_latency_ns", Json::num(o.stats.latency.percentile(95.0) as f64)),
        ("staleness", Json::num(o.stats.staleness())),
        ("requests_tracked", Json::num(o.kad.requests_tracked as f64)),
        ("requests_sent", Json::num(o.kad.requests_sent as f64)),
        ("requests_timed_out", Json::num(o.kad.requests_timed_out as f64)),
        ("requests_failed", Json::num(o.kad.requests_failed as f64)),
        ("probes_evicted", Json::num(o.kad.probes_evicted as f64)),
        ("refreshes", Json::num(o.kad.refreshes as f64)),
        ("republish_rounds", Json::num(o.kad.republish_rounds as f64)),
        ("joins", Json::num(o.joins as f64)),
        ("leaves", Json::num(o.leaves as f64)),
        ("crashes", Json::num(o.crashes as f64)),
        ("live_at_end", Json::num(o.live_at_end as f64)),
    ])
}

/// One scaling-curve row from a planet-scale arm: lookup quality plus the
/// memory-pressure gauges ("bounded memory" as numbers, not adjectives).
fn planet_row(o: &mut PlanetOutcome) -> Json {
    Json::obj(vec![
        ("nodes", Json::num(o.stats.nodes as f64)),
        ("background_total", Json::num(o.background_total as f64)),
        ("lookups", Json::num(o.stats.attempted as f64)),
        ("success_rate", Json::num(o.stats.success_rate())),
        ("mean_hops", Json::num(o.stats.mean_hops())),
        ("p95_hops", Json::num(o.stats.hops.percentile(95.0) as f64)),
        ("p95_latency_ns", Json::num(o.stats.latency.percentile(95.0) as f64)),
        ("wall_clock_ms", Json::num(o.wall_clock_ms as f64)),
        ("events_processed", Json::num(o.events_processed as f64)),
        ("events_dropped_stale", Json::num(o.events_dropped_stale as f64)),
        ("peak_queue_depth", Json::num(o.peak_queue_depth as f64)),
        ("peak_inflight_datagrams", Json::num(o.peak_inflight_datagrams as f64)),
        (
            "peak_inflight_payload_bytes",
            Json::num(o.peak_inflight_payload_bytes as f64),
        ),
        ("materialized", Json::num(o.materialized as f64)),
        ("kad_served", Json::num(o.kad_served as f64)),
        ("churn_downs", Json::num(o.churn_downs as f64)),
        ("churn_ups", Json::num(o.churn_ups as f64)),
    ])
}

fn main() {
    let args = Args::from_env();
    let lookups = args.opt_usize("lookups", 20).unwrap();
    let churn_nodes = args.opt_usize("nodes", 200).unwrap();
    // `--planet-only`: run just the planet-scale curve (CI's 100k smoke
    // uses this under a wall-clock budget) and leave BENCH_dht_churn.json
    // untouched so a smoke run can't clobber the measured mesh rows.
    let planet_only = args.flag("planet-only");

    let mut mesh_results = None;
    if !planet_only {
        println!("Kademlia lookup scaling (α=3, k=20): expect ~O(log N) request rounds");
        println!("{:<8} {:>12} {:>14} {:>10}", "N", "mean reqs", "p95 latency", "log2(N)");
        let mut means = Vec::new();
        for n in [16usize, 32, 64, 128] {
            let (mean_hops, mut lat) = run(n, lookups, 300 + n as u64);
            println!(
                "{:<8} {:>12.1} {:>14} {:>10.1}",
                n,
                mean_hops,
                lattica::util::timefmt::fmt_ns(lat.percentile(95.0)),
                (n as f64).log2()
            );
            means.push(mean_hops);
        }
        // Kademlia lookup cost ≈ K + α·log₂(N): dominated by the K-closest
        // sweep at small N, growing logarithmically after. Sub-linear check:
        // N grew 8×, requests must grow well under 8×.
        assert!(
            means[3] < means[0] * 6.0,
            "lookup cost must grow sub-linearly: {means:?}"
        );
        println!("\nshape check OK: requests grow sub-linearly with N (~K + a*log N)");

        // --------------------------------------------------------------
        // Churn scenario: control (no churn) vs 60 s session half-life.
        // --------------------------------------------------------------
        println!("\nChurn scenario: {churn_nodes} nodes, get_providers for live content");
        let mut control = churn_arm(churn_nodes, 0, 9001);
        println!("  no churn : {}", control.stats.summary());
        let mut churned = churn_arm(churn_nodes, 60, 9001);
        println!(
            "  churn 60s: {} (joins={} leaves={} crashes={} live_at_end={})",
            churned.stats.summary(),
            churned.joins,
            churned.leaves,
            churned.crashes,
            churned.live_at_end
        );
        mesh_results = Some((means, control, churned));
    }

    // ------------------------------------------------------------------
    // Planet-scale scaling curve: 1k → 10k (→ 100k with PLANET_100K=1).
    // Background nodes answer kad from the routing oracle and only
    // materialize full stacks when traffic touches them, so the big arms
    // stay within CI minutes and bounded memory.
    // ------------------------------------------------------------------
    let planet_lookups = args.opt_usize("planet-lookups", 40).unwrap();
    let mut planet_arms: Vec<usize> = vec![1_000, 10_000];
    if std::env::var_os("PLANET_100K").is_some() {
        planet_arms.push(100_000);
    } else {
        println!("\n(100k planet arm skipped; set PLANET_100K=1 to run it)");
    }
    println!("\nPlanet-scale lookup curve ({planet_lookups} lookups/arm, seeded churn)");
    let mut planet_rows = Vec::new();
    for n in planet_arms {
        let mut o = planet_scale(&PlanetConfig::sized(n, planet_lookups, 7000 + n as u64));
        println!(
            "  {:>6} nodes: {} wall={}ms peak_queue={} peak_inflight={}B materialized={}/{}",
            n,
            o.stats.summary(),
            o.wall_clock_ms,
            o.peak_queue_depth,
            o.peak_inflight_payload_bytes,
            o.materialized,
            o.background_total
        );
        // The acceptance bar applies to the 1k and 10k arms; the 100k arm
        // is a wall-clock/memory smoke and reports without gating.
        if n <= 10_000 {
            assert!(
                o.stats.success_rate() >= 0.95,
                "{n}-node planet arm below the 95% bar: {:.3}",
                o.stats.success_rate()
            );
        }
        planet_rows.push(planet_row(&mut o));
    }

    let Some((means, mut control, mut churned)) = mesh_results else {
        println!("planet-only smoke OK (BENCH_dht_churn.json left untouched)");
        return;
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("dht_churn")),
        ("scenario", Json::str("bootstrap_mesh")),
        ("lookup_interval_secs", Json::num(1.0)),
        ("duration_secs", Json::num(90.0)),
        (
            "rows",
            Json::Arr(vec![
                arm_row("no_churn", churn_nodes, 0, &mut control),
                arm_row("churn_60s", churn_nodes, 60, &mut churned),
            ]),
        ),
        (
            "scaling_mean_requests",
            Json::Arr(means.iter().map(|m| Json::num(*m)).collect()),
        ),
        ("planet_rows", Json::Arr(planet_rows)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_dht_churn.json");
    match std::fs::write(path, format!("{doc}\n")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    // Shape checks: the control arm must be essentially lossless, and the
    // churned arm must stay above the paper-grade 95% bar.
    assert!(
        control.stats.success_rate() >= 0.99,
        "no-churn lookups must succeed (got {:.3})",
        control.stats.success_rate()
    );
    assert!(
        churned.stats.success_rate() >= 0.95,
        "churned lookups must stay >= 95% (got {:.3})",
        churned.stats.success_rate()
    );
    assert!(
        control.stats.mean_hops() <= 12.0,
        "no-churn get_providers should early-exit quickly (mean hops {:.1})",
        control.stats.mean_hops()
    );
    println!("shape check OK: >=95% success under 60s-half-life churn");
}
