//! DHT scaling: Kademlia lookup hops and latency vs network size
//! (the architecture's O(log N) claim, §2).

use lattica::metrics::Histogram;
use lattica::netsim::topology::LinkProfile;
use lattica::netsim::SECOND;
use lattica::node::{run_until, LatticaNode, NodeEvent};
use lattica::protocols::kad::KadEvent;
use lattica::protocols::Ctx;
use lattica::scenarios::bootstrap_mesh;
use lattica::util::cli::Args;
use lattica::util::Rng;

fn run(n: usize, lookups: usize, seed: u64) -> (f64, Histogram) {
    let (mut world, nodes) = bootstrap_mesh(n, seed, LinkProfile::DATACENTER);
    // Let the mesh settle + everyone self-lookup happened in bootstrap.
    world.run_for(3 * SECOND);
    let mut rng = Rng::new(seed ^ 0xD47);
    let mut hops_total = 0u64;
    let mut finished = 0usize;
    let mut lat = Histogram::new();
    for _ in 0..lookups {
        let src = rng.gen_index(n);
        let dst = rng.gen_index(n);
        let target = *nodes[dst].borrow().peer_id().as_bytes();
        // Clear any leftover events from previous lookups.
        let _ = nodes[src].borrow_mut().drain_events();
        let t0 = world.net.now();
        {
            let mut nd = nodes[src].borrow_mut();
            let LatticaNode { swarm, kad, .. } = &mut *nd;
            let mut ctx = Ctx::new(swarm, &mut world.net);
            kad.find_node(&mut ctx, target);
        }
        let mut hops = None;
        run_until(&mut world, 20 * SECOND, || {
            if hops.is_none() {
                let mut nd = nodes[src].borrow_mut();
                for e in nd.drain_events() {
                    if let NodeEvent::Kad(KadEvent::QueryFinished { hops: h, .. }) = e {
                        hops = Some(h);
                    }
                }
            }
            hops.is_some()
        });
        if let Some(h) = hops {
            hops_total += h as u64;
            finished += 1;
            lat.record(world.net.now() - t0);
        }
    }
    (hops_total as f64 / finished.max(1) as f64, lat)
}

fn main() {
    let args = Args::from_env();
    let lookups = args.opt_usize("lookups", 20).unwrap();
    println!("Kademlia lookup scaling (α=3, k=20): expect ~O(log N) request rounds");
    println!("{:<8} {:>12} {:>14} {:>10}", "N", "mean reqs", "p95 latency", "log2(N)");
    let mut means = Vec::new();
    for n in [16usize, 32, 64, 128] {
        let (mean_hops, mut lat) = run(n, lookups, 300 + n as u64);
        println!(
            "{:<8} {:>12.1} {:>14} {:>10.1}",
            n,
            mean_hops,
            lattica::util::timefmt::fmt_ns(lat.percentile(95.0)),
            (n as f64).log2()
        );
        means.push(mean_hops);
    }
    // Kademlia lookup cost ≈ K + α·log₂(N): dominated by the K-closest
    // sweep at small N, growing logarithmically after. Sub-linear check:
    // N grew 8×, requests must grow well under 8×.
    assert!(
        means[3] < means[0] * 6.0,
        "lookup cost must grow sub-linearly: {means:?}"
    );
    println!("\nshape check OK: requests grow sub-linearly with N (~K + a*log N)");
}
