//! CRDT store convergence under churn and partitions (§2's eventually
//! consistent, verifiable replication).
//!
//! N replicas apply random concurrent updates; anti-entropy rounds run over
//! a random gossip ring, with a partition separating the first half from
//! the second for the first phase. Convergence = identical store digests.

use lattica::netsim::topology::LinkProfile;
use lattica::netsim::SECOND;
use lattica::scenarios::bootstrap_mesh;
use lattica::util::cli::Args;
use lattica::util::Rng;

fn main() {
    let args = Args::from_env();
    let n = args.opt_usize("nodes", 8).unwrap();
    let updates = args.opt_usize("updates", 200).unwrap();
    let (mut world, nodes) = bootstrap_mesh(n, 777, LinkProfile::FIBER);
    let mut rng = Rng::new(99);

    // Phase 1: concurrent updates, syncing only within partition halves.
    for i in 0..updates {
        let r = rng.gen_index(n);
        let mut nd = nodes[r].borrow_mut();
        match rng.gen_index(3) {
            0 => nd.crdt.gcounter("train/steps").increment(r as u64, 1),
            1 => {
                let member = format!("peer-{}", rng.gen_index(n * 2));
                nd.crdt.orset("cluster/members").add(r as u64, member.as_bytes());
            }
            _ => {
                let v = format!("ckpt-{i}");
                nd.crdt.lww("model/latest").set(v.into_bytes(), i as u64, r as u64);
            }
        }
        drop(nd);
        if i % 10 == 9 {
            // Partitioned anti-entropy: only same-half pairs sync.
            let a = rng.gen_index(n);
            let b = if a < n / 2 { rng.gen_index(n / 2) } else { n / 2 + rng.gen_index(n - n / 2) };
            if a != b {
                let peer = nodes[b].borrow().peer_id();
                let _ = nodes[a].borrow_mut().crdt_sync_with(&mut world.net, &peer);
                world.run_for(SECOND / 4);
            }
        }
    }
    world.run_for(2 * SECOND);
    let digests: Vec<_> = nodes.iter().map(|nd| nd.borrow().crdt.digest()).collect();
    let halves_diverged = digests[0] != digests[n - 1];
    println!("after partitioned phase: halves diverged = {halves_diverged}");

    // Phase 2: heal the partition — full ring sync until digests agree.
    let t0 = world.net.now();
    let mut rounds = 0;
    loop {
        rounds += 1;
        for a in 0..n {
            let b = (a + 1) % n;
            let peer = nodes[b].borrow().peer_id();
            let _ = nodes[a].borrow_mut().crdt_sync_with(&mut world.net, &peer);
        }
        world.run_for(SECOND);
        let d0 = nodes[0].borrow().crdt.digest();
        if nodes.iter().all(|nd| nd.borrow().crdt.digest() == d0) {
            break;
        }
        assert!(rounds < 20, "no convergence after {rounds} ring rounds");
    }
    let heal = (world.net.now() - t0) as f64 / 1e9;
    println!("converged after {rounds} ring rounds ({heal:.2}s virtual)");

    // Verify the merged state makes sense.
    let mut n0 = nodes[0].borrow_mut();
    let steps = n0.crdt.gcounter("train/steps").value();
    println!(
        "final: steps counter = {steps}, members = {}, latest = {:?}",
        n0.crdt.orset("cluster/members").len(),
        String::from_utf8_lossy(n0.crdt.lww("model/latest").get())
    );
    assert!(steps > 0);
    assert!(rounds <= n, "ring anti-entropy must converge within N rounds");
    println!("shape check OK: digest-verified convergence within N ring rounds");
}
