//! Federated / volunteer computing (§3): hospitals exchange model updates
//! through content addressing while coordinating round state in the CRDT
//! store — no server, NATs everywhere, stragglers tolerated.
//!
//! Each "hospital" trains locally (simulated delta), publishes its update
//! as a CID blob, and records (round, participant) in the replicated CRDT
//! store. When the OR-set for a round reaches quorum, every hospital
//! fetches the updates it is missing and folds them into its model.
//! After each fold, hospital 0 audits the cohort by pulling every peer's
//! model digest through the registered `fed` service — a typed unary
//! call over the NAT-traversed circuits, not an out-of-band assertion.
//!
//! Run: cargo run --release --example federated_learning

use lattica::content::{Cid, DagManifest};
use lattica::multiaddr::Multiaddr;
use lattica::netsim::nat::NatType;
use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::{LatticaNode, NodeConfig};
use lattica::rpc::{Outcome, Service, Status, Stub};
use lattica::scenarios::stub_call_blocking;
use lattica::util::Rng;
use std::cell::RefCell;
use std::rc::Rc;

const HOSPITALS: usize = 4;
const ROUNDS: usize = 3;
const UPDATE_BYTES: usize = 512 * 1024;

fn main() -> anyhow::Result<()> {
    let mut topo = TopologyBuilder::paper_regions();
    let h_relay = topo.public_host(0, LinkProfile::DATACENTER);
    let hosts: Vec<u32> = (0..HOSPITALS)
        .map(|i| {
            let nat = topo.nat(1 + i % 2, NatType::PortRestrictedCone, LinkProfile::FIBER);
            topo.natted_host(nat, LinkProfile::UNLIMITED)
        })
        .collect();
    let mut world = World::new(topo.build(4242));
    let relay = LatticaNode::spawn(&mut world, h_relay, NodeConfig::relay(1));
    let hospitals: Vec<_> = hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| LatticaNode::spawn(&mut world, h, NodeConfig::with_seed(30 + i as u64)))
        .collect();

    let relay_ma = relay.borrow().listen_addr();
    let relay_peer = relay.borrow().peer_id();
    for h in &hospitals {
        h.borrow_mut().dial(&mut world.net, &relay_ma)?;
    }
    world.run_for(2 * SECOND);
    for h in &hospitals {
        h.borrow_mut().swarm.relay_reserve(&mut world.net, &relay_peer)?;
    }
    world.run_for(SECOND);
    // Full mesh over relay circuits, retried until verified.
    for attempt in 0..10 {
        let mut missing = 0;
        for i in 0..HOSPITALS {
            for j in 0..HOSPITALS {
                if i == j {
                    continue;
                }
                let target = hospitals[j].borrow().peer_id();
                if !hospitals[i].borrow().swarm.is_connected(&target) {
                    missing += 1;
                    if attempt > 0 || i < j {
                        let circuit = Multiaddr::circuit(relay_ma.clone(), target);
                        let _ = hospitals[i].borrow_mut().dial(&mut world.net, &circuit);
                    }
                }
            }
        }
        if missing == 0 && attempt > 0 {
            break;
        }
        world.run_for(2 * SECOND);
    }
    println!("{HOSPITALS} hospitals meshed through the relay (all port-restricted NATs)");

    // Every hospital serves its current model digest over the typed
    // service layer (Unavailable until the first round folds).
    let digest_cells: Vec<Rc<RefCell<Vec<u8>>>> = hospitals
        .iter()
        .map(|h| {
            let cell = Rc::new(RefCell::new(Vec::new()));
            let served = cell.clone();
            h.borrow_mut().register_service(Service::new("fed").unary(
                "digest",
                move |_node, _net, _ctx, _payload| {
                    let d = served.borrow();
                    if d.is_empty() {
                        Outcome::fail(Status::Unavailable, "no round folded yet")
                    } else {
                        Outcome::reply(d.clone())
                    }
                },
            ));
            cell
        })
        .collect();

    let peers: Vec<_> = hospitals.iter().map(|h| h.borrow().peer_id()).collect();
    let mut rng = Rng::new(7);
    let mut model_digest = vec![0u8; 32]; // folded-update commitment per node

    for round in 1..=ROUNDS {
        println!("-- round {round} --");
        // 1. Local training + publish update.
        let mut roots: Vec<Cid> = Vec::new();
        for (i, h) in hospitals.iter().enumerate() {
            let update = rng.gen_bytes(UPDATE_BYTES);
            let root = h.borrow_mut().publish_blob(
                &mut world.net,
                &format!("update/r{round}/h{i}"),
                round as u64,
                &update,
                128 * 1024,
            );
            roots.push(root);
            // 2. Record participation in the CRDT store.
            let mut nd = h.borrow_mut();
            nd.crdt
                .orset(&format!("round/{round}/participants"))
                .add(i as u64, root.as_bytes());
            nd.crdt.gcounter("rounds/completed").increment(i as u64, 1);
        }
        // 3. Anti-entropy ring until participation state converges
        //    (a ring needs N-1 rounds to flood; run N).
        for _ in 0..HOSPITALS {
            for i in 0..HOSPITALS {
                let peer = peers[(i + 1) % HOSPITALS];
                hospitals[i].borrow_mut().crdt_sync_with(&mut world.net, &peer)?;
            }
            world.run_for(SECOND);
        }
        let quorum_key = format!("round/{round}/participants");
        for h in &hospitals {
            let n = h.borrow_mut().crdt.orset(&quorum_key).len();
            assert_eq!(n, HOSPITALS, "round state must converge");
        }
        println!("   CRDT round state converged ({HOSPITALS} participants)");
        // 4. Fetch all updates recorded in the OR-set (idempotent driver).
        let t0 = world.net.now();
        let deadline = world.net.now() + 200 * SECOND;
        loop {
            let mut all_done = true;
            for (i, h) in hospitals.iter().enumerate() {
                let cids: Vec<Cid> = {
                    let mut nd = h.borrow_mut();
                    nd.crdt
                        .orset(&quorum_key)
                        .iter()
                        .filter_map(|b| Cid::from_bytes(b).ok())
                        .collect()
                };
                let providers: Vec<_> = peers
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != i)
                    .map(|(_, p)| *p)
                    .collect();
                for c in cids {
                    if !h.borrow_mut().sync_blob(&mut world.net, c, &providers) {
                        all_done = false;
                    }
                }
            }
            if all_done || world.net.now() >= deadline {
                break;
            }
            world.run_for(SECOND / 4);
        }
        let ok = hospitals.iter().all(|h| {
            let n = h.borrow();
            roots.iter().all(|r| {
                DagManifest::load(&n.blockstore, r)
                    .map(|m| m.is_complete(&n.blockstore))
                    .unwrap_or(false)
            })
        });
        assert!(ok, "round {round}: updates did not replicate");
        let dt = (world.net.now() - t0) as f64 / 1e9;
        // 5. Fold: everyone hashes the same update set → identical digests.
        use lattica::crypto::sha256::Sha256;
        let mut digests = Vec::new();
        for h in &hospitals {
            let n = h.borrow();
            let mut hasher = Sha256::new();
            hasher.update(&model_digest);
            let mut sorted = roots.clone();
            sorted.sort();
            for r in &sorted {
                let m = DagManifest::load(&n.blockstore, r).unwrap();
                hasher.update(m.assemble(&n.blockstore).unwrap());
            }
            digests.push(hasher.finalize().to_vec());
        }
        assert!(digests.windows(2).all(|w| w[0] == w[1]), "aggregation must agree");
        for (cell, d) in digest_cells.iter().zip(&digests) {
            *cell.borrow_mut() = d.clone();
        }
        model_digest = digests[0].clone();
        // Audit over RPC: hospital 0 pulls every peer's digest through the
        // `fed` service and verifies cohort agreement end-to-end.
        for (j, peer) in peers.iter().enumerate().skip(1) {
            let mut stub = Stub::new("fed", vec![*peer]);
            let done =
                stub_call_blocking(&mut world, &hospitals[0], &mut stub, "digest", b"", 10 * SECOND)
                    .expect("digest query");
            assert_eq!(done.status, Status::Ok, "hospital {j}: {}", done.detail);
            assert_eq!(done.payload, model_digest, "hospital {j} digest mismatch");
        }
        println!(
            "   all {HOSPITALS} hospitals aggregated {} updates in {dt:.2}s (virtual); digest {} (cross-checked via fed.digest)",
            HOSPITALS,
            lattica::util::hex::encode_prefix(&model_digest, 12)
        );
    }
    let completed = hospitals[0].borrow_mut().crdt.gcounter("rounds/completed").value();
    println!("federated rounds recorded in CRDT store: {completed} participant-rounds");
    println!("federated_learning OK");
    Ok(())
}
