//! Latency-aware sharded inference demo (DESIGN.md §Inference plane).
//!
//! Two pipeline stages, each with a replica in the client's region and
//! one across a continent. Every replica advertises its layer range on
//! the layer-ads gossip topic + DHT provider buckets; the client's
//! [`ChainClient`] assembles the lowest-latency chain covering the full
//! layer range, streams a prompt through it token-by-token with KV state
//! resident on the stages, then survives a mid-stream stage kill via
//! splice-repair + replay.
//!
//! Needs no artifacts: the synthetic [`SimModel`] stands in for the
//! stubbed PJRT runtime.
//! Run: cargo run --release --example sharded_inference

use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, MILLI, SECOND};
use lattica::node::{LatticaNode, NodeConfig};
use lattica::route::{ChainClient, RouteMode, RouteShard, ShardSpec, SimModel};
use lattica::scenarios::Node;

type Replica = (Node, RouteShard, &'static str);

/// Advance the world in 50 ms steps, ticking every live stage and
/// feeding the client's events through the chain client.
fn drive(
    world: &mut World,
    client: &Node,
    chain: &mut ChainClient,
    replicas: &[Replica],
    steps: usize,
) {
    for _ in 0..steps {
        world.run_for(50 * MILLI);
        for (node, shard, _) in replicas {
            node.borrow_mut().drain_events();
            let mut n = node.borrow_mut();
            shard.tick(&mut n, &mut world.net);
        }
        let evs = client.borrow_mut().drain_events();
        let mut n = client.borrow_mut();
        for ev in evs {
            chain.on_event(&mut n, &mut world.net, &ev);
        }
        chain.tick(&mut n, &mut world.net);
    }
}

fn main() {
    let model = SimModel::tiny();
    let split = model.n_layer / 2;
    println!(
        "model {}: {} layers, split at {split} across 2 stages",
        model.model_id, model.n_layer
    );

    // Client in region 0; each stage has a local (region 0) and a remote
    // (region 1/2, ~75 ms one-way away) replica.
    let mut t = TopologyBuilder::paper_regions();
    let client_host = t.public_host(0, LinkProfile::FIBER);
    let specs = [
        ((0, split), 1u32, "stage-0 remote"),
        ((0, split), 0, "stage-0 local"),
        ((split, model.n_layer), 2, "stage-1 remote"),
        ((split, model.n_layer), 0, "stage-1 local"),
    ];
    let hosts: Vec<u32> = specs
        .iter()
        .map(|&(_, region, _)| t.public_host(region as usize, LinkProfile::FIBER))
        .collect();
    let mut world = World::new(t.build(7));
    let client = LatticaNode::spawn(&mut world, client_host, NodeConfig::with_seed(100));
    let replicas: Vec<Replica> = specs
        .iter()
        .zip(&hosts)
        .enumerate()
        .map(|(i, (&(layers, region, label), &host))| {
            let node = LatticaNode::spawn(&mut world, host, NodeConfig::with_seed(101 + i as u64));
            let shard = {
                let mut n = node.borrow_mut();
                RouteShard::install(
                    &mut n,
                    &mut world.net,
                    ShardSpec {
                        model: model.clone(),
                        layers,
                        region,
                        capacity_entries: 1 << 16,
                    },
                )
            };
            (node, shard, label)
        })
        .collect();
    let entry = lattica::protocols::kad::PeerEntry {
        id: client.borrow().peer_id(),
        host: client_host,
        port: 4001,
    };
    for (node, _, _) in &replicas {
        node.borrow_mut().bootstrap(&mut world.net, entry.clone());
    }
    world.run_for(3 * SECOND);

    let mut chain = {
        let mut n = client.borrow_mut();
        ChainClient::new(&mut n, &mut world.net, model.clone(), 0, RouteMode::Routed)
    };

    // Let ads gossip out and RTT probes land.
    drive(&mut world, &client, &mut chain, &replicas, 100);
    println!("\nlayer ads known to the client:");
    for ad in chain.book.ads_for(&model.model_id) {
        println!(
            "  {} layers [{}, {})  region {}  load {}%",
            ad.peer, ad.layers.0, ad.layers.1, ad.region, ad.load
        );
    }

    // One request: the router should pick the all-local chain.
    let prompt = vec![5u32, 9, 2, 7];
    let gen_len = 8;
    let want = model.reference_generate(&prompt, gen_len);
    let id = {
        let mut n = client.borrow_mut();
        chain.start(&mut n, &mut world.net, prompt.clone(), gen_len)
    };
    drive(&mut world, &client, &mut chain, &replicas, 4);
    println!("\nchosen chain for request {id}:");
    for (hop, peer) in chain.chain_of(id).iter().enumerate() {
        let who = replicas
            .iter()
            .find(|(n, _, _)| n.borrow().peer_id() == *peer)
            .map(|(_, _, l)| *l)
            .unwrap_or("?");
        println!("  hop {hop}: {peer} ({who})");
    }

    // Kill the tail stage's local replica mid-stream: the stage above it
    // reports a fault upstream, the client quarantines the dead hop,
    // splices in the remote holder and replays from the last acked token.
    while chain.partially_acked() == 0 && chain.in_flight() > 0 {
        drive(&mut world, &client, &mut chain, &replicas, 1);
    }
    let (victim, live) = replicas.split_last().expect("replicas");
    println!(
        "\nkilling mid-stream: {} ({})",
        victim.2,
        victim.0.borrow().peer_id()
    );
    let eid = {
        let mut n = victim.0.borrow_mut();
        n.shutdown(&mut world.net, false);
        n.endpoint_id()
    };
    world.remove_endpoint(eid);

    let deadline = world.net.now() + 120 * SECOND;
    while chain.in_flight() > 0 && world.net.now() < deadline {
        drive(&mut world, &client, &mut chain, live, 1);
    }
    let done = chain.completed.first().expect("request must complete");
    println!("\nemitted tokens: {:?}", done.tokens);
    println!("oracle tokens:  {want:?}");
    println!(
        "repairs: {}  ttft: {:.2} ms  (completed at t = {:.2}s virtual)",
        done.repairs,
        done.ttft as f64 / 1e6,
        done.finished as f64 / 1e9
    );
    assert_eq!(done.tokens, want, "replayed output must match the oracle");
    assert!(done.repairs >= 1, "the kill must have forced a repair");
    println!("OK: stage death was masked by splice-repair + replay");
}
