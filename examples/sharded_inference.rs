//! Sharded inference demo (Fig. 1(4)): the transformer split across two
//! shard stages with replicas, served over the typed service layer with
//! automatic stub failover — each shard registers the `shard` service
//! ([`ShardServer::into_service`]) and the client's pipeline drives one
//! retrying stub per stage. See `benches/sharded_inference.rs` for the
//! measured version; this example walks through the moving parts and
//! prints the predictions.
//!
//! Requires `make artifacts`.
//! Run: cargo run --release --example sharded_inference

use lattica::netsim::topology::LinkProfile;
use lattica::netsim::SECOND;
use lattica::node::NodeEvent;
use lattica::runtime::Engine;
use lattica::scenarios::bootstrap_mesh;
use lattica::shard::{PipelineClient, ShardServer};
use std::cell::RefCell;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let engine = Rc::new(RefCell::new(Engine::load(dir)?));
    let cfg = engine.borrow().manifest.config.clone();
    let params = engine.borrow().manifest.load_init_params()?;
    let split = cfg.n_layer / 2;

    let (mut world, nodes) = bootstrap_mesh(5, 99, LinkProfile::DATACENTER);
    let client = nodes[0].clone();
    println!(
        "pipeline: stage0 = embed+layers[0..{split}] (2 replicas), stage1 = layers[{split}..{}]+logits (2 replicas)",
        cfg.n_layer
    );
    let stages = vec![
        vec![nodes[1].borrow().peer_id(), nodes[2].borrow().peer_id()],
        vec![nodes[3].borrow().peer_id(), nodes[4].borrow().peer_id()],
    ];
    for (i, nd) in nodes[1..].iter().enumerate() {
        let stage = i / 2;
        let (svc, _handle) = ShardServer::new(
            engine.clone(),
            if stage == 0 { (0, split) } else { (split, cfg.n_layer) },
            stage == 0,
            stage == 1,
            params.clone(),
        )
        .into_service();
        nd.borrow_mut().register_service(svc);
    }
    world.run_for(SECOND);

    let mut pipeline = PipelineClient::new(stages);
    // An arithmetic-sequence prompt (the synthetic training task).
    let delta = 3i32;
    let tokens: Vec<i32> = (0..cfg.seq_len as i32).map(|i| (5 + delta * i) % cfg.vocab as i32).collect();
    println!("prompt: arithmetic sequence mod {} with delta {delta}", cfg.vocab);

    for q in 0..4u64 {
        if q == 2 {
            // Kill stage-0 replica 0 mid-demo: the stub fails over.
            let dead = nodes[1].borrow().endpoint_id();
            world.remove_endpoint(dead);
            println!("!! killed stage-0 replica 0 — requests continue via replica 1");
        }
        {
            let mut c = client.borrow_mut();
            pipeline.infer(&mut c, &mut world.net, tokens.clone())?;
        }
        let deadline = world.net.now() + 60 * SECOND;
        while pipeline.completed.len() <= q as usize && world.net.now() < deadline {
            world.run_for(SECOND / 50);
            let evs = client.borrow_mut().drain_events();
            let mut c = client.borrow_mut();
            for e in &evs {
                if let NodeEvent::Rpc(ev) = e {
                    pipeline.on_rpc_event(&mut c, &mut world.net, ev);
                }
            }
            // Drive the per-stage stubs' retry/failover timers.
            pipeline.tick(&mut c, &mut world.net);
        }
        let (rid, logits, started) = pipeline.completed.last().expect("completed");
        let vals = logits.as_f32()?;
        let argmax = vals
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        let expect = (tokens[cfg.seq_len - 1] + delta) % cfg.vocab as i32;
        println!(
            "request {rid}: predicted next token {argmax} (sequence-correct would be {expect}), latency {}",
            lattica::util::timefmt::fmt_ns(world.net.now() - started)
        );
    }
    assert_eq!(pipeline.completed.len(), 4);
    assert!(pipeline.failed.is_empty());
    println!("sharded_inference OK (untrained weights predict arbitrarily; failover masked the kill)");
    Ok(())
}
