//! End-to-end driver (DESIGN.md §7): collaborative training + decentralized
//! model distribution + NAT-traversed inference serving, all layers live.
//!
//! Topology: a public relay/rendezvous node, a training node, and three
//! inference clusters behind different NAT types. The trainer steps the
//! real AOT-compiled transformer (`train_step.hlo.txt`, with its Pallas
//! kernels inside) via PJRT, logs the loss curve, publishes each
//! checkpoint as CID-addressed chunks, and announces it over gossip.
//! Inference clusters fetch via Bitswap (over relay circuits when NATed),
//! hot-swap weights, and serve inference RPCs from an edge client behind a
//! symmetric NAT.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example collaborative_rl -- --steps 120
//!
//! Results are recorded in EXPERIMENTS.md.

use lattica::content::{Chunking, DEFAULT_CHUNK_SIZE};
use lattica::model::{load_checkpoint, CheckpointPublisher, ModelAnnouncement, MODEL_SERVICE};
use lattica::multiaddr::Multiaddr;
use lattica::netsim::nat::NatType;
use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::{run_until, LatticaNode, NodeConfig, NodeEvent};
use lattica::protocols::gossip::GossipEvent;
use lattica::protocols::Ctx;
use lattica::rpc::{CallOptions, RetryPolicy, Status, Stub};
use lattica::runtime::Engine;
use lattica::scenarios::stub_call_blocking;
use lattica::shard::{ShardRequest, ShardServer, SHARD_SERVICE};
use lattica::trainer::Trainer;
use lattica::util::cli::Args;
use std::cell::RefCell;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.opt_usize("steps", 120)?;
    let ckpt_every = args.opt_usize("ckpt-every", 40)?;

    let dir = std::path::Path::new("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let engine = Rc::new(RefCell::new(Engine::load(dir)?));
    let cfg = engine.borrow().manifest.config.clone();
    println!(
        "model: vocab={} d={} layers={} heads={} seq={} ({} params)",
        cfg.vocab, cfg.d_model, cfg.n_layer, cfg.n_head, cfg.seq_len,
        engine.borrow().manifest.param_elements()
    );

    // ---- Topology: relay + trainer public; clusters A–C + client NATed.
    let mut topo = TopologyBuilder::paper_regions();
    let h_relay = topo.public_host(0, LinkProfile::DATACENTER);
    let h_trainer = topo.public_host(0, LinkProfile::DATACENTER);
    let nat_a = topo.nat(1, NatType::FullCone, LinkProfile::FIBER);
    let h_a = topo.natted_host(nat_a, LinkProfile::UNLIMITED);
    let nat_b = topo.nat(1, NatType::PortRestrictedCone, LinkProfile::FIBER);
    let h_b = topo.natted_host(nat_b, LinkProfile::UNLIMITED);
    let nat_c = topo.nat(2, NatType::Symmetric, LinkProfile::FIBER);
    let h_c = topo.natted_host(nat_c, LinkProfile::UNLIMITED);
    let nat_cl = topo.nat(2, NatType::Symmetric, LinkProfile::BROADBAND);
    let h_client = topo.natted_host(nat_cl, LinkProfile::UNLIMITED);
    let mut world = World::new(topo.build(20250710));

    let relay = LatticaNode::spawn(&mut world, h_relay, NodeConfig::relay(1));
    let trainer_node = LatticaNode::spawn(&mut world, h_trainer, NodeConfig::with_seed(2));
    let clusters: Vec<_> = [(h_a, 3u64), (h_b, 4), (h_c, 5)]
        .iter()
        .map(|&(h, s)| LatticaNode::spawn(&mut world, h, NodeConfig::with_seed(s)))
        .collect();
    let edge = LatticaNode::spawn(&mut world, h_client, NodeConfig::with_seed(6));

    // ---- Connectivity: everyone dials the relay; NATed nodes reserve.
    let relay_ma = relay.borrow().listen_addr();
    let relay_peer = relay.borrow().peer_id();
    for n in clusters.iter().chain([&trainer_node, &edge]) {
        n.borrow_mut().dial(&mut world.net, &relay_ma)?;
    }
    world.run_for(2 * SECOND);
    for n in clusters.iter().chain([&edge]) {
        n.borrow_mut().swarm.relay_reserve(&mut world.net, &relay_peer)?;
    }
    world.run_for(SECOND);
    println!("mesh up: relay + trainer + 3 NATed clusters + edge client");

    // Clusters subscribe to checkpoint announcements; trainer connects to
    // each cluster through a relay circuit (they are NATed).
    for n in clusters.iter() {
        let mut nd = n.borrow_mut();
        let LatticaNode { swarm, gossip, .. } = &mut *nd;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        gossip.subscribe(&mut ctx, &lattica::model::model_topic("policy"));
    }
    {
        let mut t = trainer_node.borrow_mut();
        let LatticaNode { swarm, gossip, .. } = &mut *t;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        gossip.subscribe(&mut ctx, &lattica::model::model_topic("policy"));
    }
    for n in clusters.iter() {
        let peer = n.borrow().peer_id();
        let circuit = Multiaddr::circuit(relay_ma.clone(), peer);
        trainer_node.borrow_mut().dial(&mut world.net, &circuit)?;
    }
    world.run_for(2 * SECOND);

    // ---- Install shard servers (full model per cluster) with init
    // params: each cluster registers the `shard` service; the shared
    // handle hot-swaps parameters in place when a checkpoint syncs.
    let init_params = engine.borrow().manifest.load_init_params()?;
    let mut shard_handles = Vec::new();
    for n in clusters.iter() {
        let (svc, handle) = ShardServer::new(
            engine.clone(),
            (0, cfg.n_layer),
            true,
            true,
            init_params.clone(),
        )
        .into_service();
        n.borrow_mut().register_service(svc);
        shard_handles.push(handle);
    }

    // ---- Model-sync control plane: the trainer holds a long-lived
    // publisher and serves `model.latest` as a registered service, so
    // any node can pull the newest checkpoint pointer without waiting
    // for gossip.
    let publisher = Rc::new(RefCell::new(CheckpointPublisher::with_chunking(
        "policy",
        Chunking::Fixed(DEFAULT_CHUNK_SIZE),
    )));
    trainer_node
        .borrow_mut()
        .register_service(CheckpointPublisher::service(publisher.clone()));

    // ---- Edge client connects to cluster A via circuit + DCUtR upgrade.
    let a_peer = clusters[0].borrow().peer_id();
    let circuit_a = Multiaddr::circuit(relay_ma.clone(), a_peer);
    edge.borrow_mut().dial(&mut world.net, &circuit_a)?;
    run_until(&mut world, 5 * SECOND, || edge.borrow().swarm.is_connected(&a_peer));
    let edge_cid = edge.borrow().swarm.conns_to(&a_peer).first().copied();
    if let Some(cid) = edge_cid {
        let mut e = edge.borrow_mut();
        let LatticaNode { swarm, dcutr, .. } = &mut *e;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        let _ = dcutr.upgrade(&mut ctx, cid, &a_peer);
    }
    world.run_for(2 * SECOND);

    // ---- Training loop with periodic publication.
    let mut trainer = Trainer::new(&engine.borrow(), 99)?;
    let mut version = 0u64;
    let mut sync_latencies = Vec::new();
    println!("\nstep  loss      (checkpoint events inline)");
    for step in 1..=steps {
        let loss = trainer.step(&mut engine.borrow_mut())?;
        if step % 10 == 0 || step == 1 {
            println!("{step:>4}  {loss:.4}");
        }
        world.run_for(SECOND / 10); // training time passes on the mesh too

        if step % ckpt_every == 0 || step == steps {
            version += 1;
            let t0 = world.net.now();
            let root = {
                let mut tn = trainer_node.borrow_mut();
                publisher
                    .borrow_mut()
                    .publish_params(&mut tn, &mut world.net, version, &trainer.params)
                    .0
            };
            println!("      ↳ published ckpt v{version} ({root})");
            // Clusters: hear announcement → fetch → hot-swap.
            let trainer_peer = trainer_node.borrow().peer_id();
            let mut synced = vec![false; clusters.len()];
            let sync_deadline = world.net.now() + 60 * SECOND;
            while !synced.iter().all(|&s| s) && world.net.now() < sync_deadline {
                world.run_for(SECOND / 10);
                for (i, c) in clusters.iter().enumerate() {
                    if synced[i] {
                        continue;
                    }
                    let anns: Vec<ModelAnnouncement> = c
                        .borrow_mut()
                        .drain_events()
                        .into_iter()
                        .filter_map(|e| match e {
                            NodeEvent::Gossip(GossipEvent::Received { data, .. }) => {
                                ModelAnnouncement::decode(&data).ok()
                            }
                            _ => None,
                        })
                        .collect();
                    for ann in anns {
                        if ann.version == version {
                            c.borrow_mut().fetch_blob(&mut world.net, ann.root, vec![trainer_peer]);
                        }
                    }
                    // Once the manifest is local, fetch chunks; once all
                    // chunks are local, swap weights.
                    let have_manifest = c.borrow().blockstore.has(&root);
                    if have_manifest {
                        let complete = {
                            let n = c.borrow();
                            lattica::content::DagManifest::load(&n.blockstore, &root)
                                .map(|m| m.is_complete(&n.blockstore))
                                .unwrap_or(false)
                        };
                        if complete {
                            let params = {
                                let n = c.borrow();
                                load_checkpoint(&n, &engine.borrow().manifest, &root).unwrap()
                            };
                            // Hot-swap through the service handle: the
                            // registered `shard` service keeps serving,
                            // now with the new weights.
                            shard_handles[i].borrow_mut().swap_params(params);
                            synced[i] = true;
                        } else {
                            let _ = c
                                .borrow_mut()
                                .fetch_manifest_chunks(&mut world.net, &root, vec![trainer_peer]);
                        }
                    }
                }
            }
            assert!(synced.iter().all(|&s| s), "clusters failed to sync v{version}");
            let dt = (world.net.now() - t0) as f64 / 1e9;
            sync_latencies.push(dt);
            println!("      ↳ all 3 clusters synced v{version} in {dt:.2}s (virtual)");
        }
    }

    // ---- Serve inference from the edge client against cluster A,
    // through a retrying stub (the NAT-traversed path makes `Unavailable`
    // blips survivable instead of fatal).
    let tokens: Vec<i32> = (0..cfg.seq_len as i32).map(|i| (7 + 2 * i) % cfg.vocab as i32).collect();
    let n_queries = 10;
    let mut latencies = Vec::new();
    let mut shard_stub = Stub::new(SHARD_SERVICE, vec![a_peer]).with_options(CallOptions {
        deadline: 20 * SECOND,
        retry: RetryPolicy::idempotent(),
        ..CallOptions::default()
    });
    for q in 0..n_queries {
        let req = ShardRequest { request_id: q, tokens: tokens.clone(), hidden: None };
        let t0 = world.net.now();
        let done = stub_call_blocking(
            &mut world,
            &edge,
            &mut shard_stub,
            "forward",
            req.encode(),
            20 * SECOND,
        )
        .expect("inference response");
        anyhow::ensure!(
            done.status == Status::Ok,
            "inference failed: {:?} ({})",
            done.status,
            done.detail
        );
        let logits = lattica::runtime::Tensor::decode(&done.payload)?;
        assert_eq!(logits.shape, vec![1, cfg.vocab]);
        latencies.push((world.net.now() - t0) as f64 / 1e6);
    }

    // ---- Pull path of the model-sync control plane: ask the trainer's
    // registered `model` service for the latest announcement and check it
    // matches the final published version.
    let trainer_ma = trainer_node.borrow().listen_addr();
    let trainer_peer = trainer_node.borrow().peer_id();
    edge.borrow_mut().dial(&mut world.net, &trainer_ma)?;
    run_until(&mut world, 5 * SECOND, || {
        edge.borrow().swarm.is_connected(&trainer_peer)
    });
    let mut model_stub = Stub::new(MODEL_SERVICE, vec![trainer_peer]);
    let done =
        stub_call_blocking(&mut world, &edge, &mut model_stub, "latest", b"policy", 10 * SECOND)
            .expect("model.latest response");
    anyhow::ensure!(done.status == Status::Ok, "model.latest failed: {}", done.detail);
    let latest = ModelAnnouncement::decode(&done.payload)?;
    assert_eq!(latest.version, version, "control plane must serve the newest checkpoint");
    println!("model.latest → v{} ({})", latest.version, latest.root);
    // The trained model should confidently predict the arithmetic sequence:
    // check the served logits argmax matches the next token.
    let first_loss = trainer.losses.first().copied().unwrap_or(f32::NAN);
    let last_loss = *trainer.losses.last().unwrap();
    let mean_lat = latencies.iter().sum::<f64>() / latencies.len() as f64;

    println!("\n==== end-to-end summary ====");
    println!("training:   {} steps, loss {first_loss:.3} → {last_loss:.3}", steps);
    println!(
        "model sync: {} checkpoints, mean cluster sync {:.2}s",
        sync_latencies.len(),
        sync_latencies.iter().sum::<f64>() / sync_latencies.len() as f64
    );
    println!(
        "serving:    {n_queries} NAT-traversed inference calls, mean latency {mean_lat:.1} ms (virtual)"
    );
    assert!(last_loss < first_loss, "training must reduce loss");
    println!("collaborative_rl OK");
    Ok(())
}
