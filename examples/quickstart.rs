//! Quickstart: the minimal Lattica deployment.
//!
//! Boots two nodes on the simulated network, connects them, serves a
//! unary RPC through the typed service layer, and publishes + fetches a
//! content-addressed blob — the three SDK surfaces (connectivity,
//! services, content) in ~80 lines.
//!
//! The RPC surface has two halves and no raw event matching:
//!
//! * **Server:** [`LatticaNode::register_service`] installs named
//!   handlers. A handler gets a `RequestCtx` (peer identity, absolute
//!   deadline as propagated from the wire, traffic class) and returns an
//!   `Outcome` — reply payload, failure status + detail, or deferred.
//!   Requests whose deadline already passed are dropped before any
//!   handler runs.
//! * **Client:** a [`Stub`] wraps a service + provider list and layers
//!   per-call deadlines, idempotent retries with backoff + jitter,
//!   hedged second requests and multi-target failover over the wire
//!   protocol. Feed it node events and `tick` it from your drive loop
//!   (or use `scenarios::stub_call_blocking` for linear code like this).
//!
//! **Overload:** a service can cap its admitted rate with
//! [`Service::with_admission`] (or node-wide via `NodeConfig
//! { admission_rate, .. }`); excess requests are rejected *before
//! payload decode* with `Status::Overloaded` plus a retry-after hint,
//! and a handler that defers work can answer `Reply::overloaded` when
//! its own queue is full. Stubs honor the pushback automatically —
//! backing off, failing over to a quieter replica and suppressing
//! hedges — so a saturated server sheds load instead of melting down
//! (see DESIGN.md §Overload & admission control).
//!
//! Run: `cargo run --release --example quickstart`

use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::{run_until, LatticaNode, NodeConfig};
use lattica::rpc::{AdmissionPolicy, Outcome, Service, Status, Stub};
use lattica::scenarios::stub_call_blocking;

fn main() -> anyhow::Result<()> {
    // 1. A two-host world: one LAN region.
    let mut topo = TopologyBuilder::new(1);
    let h1 = topo.public_host(0, LinkProfile::DATACENTER);
    let h2 = topo.public_host(0, LinkProfile::DATACENTER);
    let mut world = World::new(topo.build(7));

    // 2. Two nodes; the server registers a greeter service. Handlers are
    //    dispatched inline by the node's ServiceRouter — no event loop,
    //    no match on raw RPC events.
    let server = LatticaNode::spawn(&mut world, h1, NodeConfig::with_seed(1));
    let client = LatticaNode::spawn(&mut world, h2, NodeConfig::with_seed(2));
    //    The admission policy caps the service at 100 admitted requests
    //    per second; anything past the burst is rejected before payload
    //    decode with `Status::Overloaded` and a retry-after hint.
    server.borrow_mut().register_service(
        Service::new("greeter")
            .with_admission(AdmissionPolicy::rate(100.0, 16.0))
            .unary("hello", |_node, _net, _ctx, payload| {
                Outcome::reply(format!("hello, {}!", String::from_utf8_lossy(&payload)))
            }),
    );

    // 3. Dial (multiaddr carries transport + expected peer id).
    let server_ma = server.borrow().listen_addr();
    println!("dialing {server_ma}");
    client.borrow_mut().dial(&mut world.net, &server_ma)?;
    let server_peer = server.borrow().peer_id();
    assert!(run_until(&mut world, 5 * SECOND, || client
        .borrow()
        .swarm
        .is_connected(&server_peer)));
    println!("connected to {server_peer} (Noise-authenticated)");

    // 4. Unary RPC through a stub. The default options give the call a
    //    10 s budget that rides the wire; see `CallOptions` for retry,
    //    hedging and failover policies.
    let mut greeter = Stub::new("greeter", vec![server_peer]);
    let done =
        stub_call_blocking(&mut world, &client, &mut greeter, "hello", b"lattica", 5 * SECOND)
            .expect("rpc response");
    assert_eq!(done.status, Status::Ok);
    println!(
        "rpc response: {:?} (rtt {}, {} attempt)",
        String::from_utf8_lossy(&done.payload),
        lattica::util::timefmt::fmt_ns(done.rtt),
        done.attempts,
    );

    // 5. Content: publish on the server, fetch by CID on the client.
    let asset = b"model weights would go here".repeat(1000);
    let root = server
        .borrow_mut()
        .publish_blob(&mut world.net, "demo-asset", 1, &asset, 8 * 1024);
    println!("published {} as {root}", lattica::util::timefmt::fmt_bytes(asset.len() as u64));
    client
        .borrow_mut()
        .fetch_blob(&mut world.net, root, vec![server_peer]);
    run_until(&mut world, 5 * SECOND, || client.borrow().blockstore.has(&root));
    client
        .borrow_mut()
        .fetch_manifest_chunks(&mut world.net, &root, vec![server_peer])?;
    assert!(run_until(&mut world, 10 * SECOND, || {
        let c = client.borrow();
        lattica::content::DagManifest::load(&c.blockstore, &root)
            .map(|m| m.is_complete(&c.blockstore))
            .unwrap_or(false)
    }));
    let (fetched, n_chunks) = {
        let c = client.borrow();
        let m = lattica::content::DagManifest::load(&c.blockstore, &root)?;
        (m.assemble(&c.blockstore)?, m.chunks.len())
    };
    assert_eq!(fetched, asset);
    println!("fetched + verified {n_chunks} chunks by CID — quickstart OK");
    Ok(())
}
