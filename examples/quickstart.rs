//! Quickstart: the minimal Lattica deployment.
//!
//! Boots two nodes on the simulated network, connects them, round-trips a
//! unary RPC, and publishes + fetches a content-addressed blob — the three
//! SDK surfaces (connectivity, RPC, content) in ~80 lines.
//!
//! Run: `cargo run --release --example quickstart`

use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::{run_until, App, LatticaNode, NodeConfig, NodeEvent};
use lattica::protocols::Ctx;
use lattica::rpc::{RpcEvent, Status};

struct Greeter;

impl App for Greeter {
    fn handle(
        &mut self,
        node: &mut LatticaNode,
        net: &mut lattica::netsim::Net,
        ev: NodeEvent,
    ) -> Option<NodeEvent> {
        if let NodeEvent::Rpc(RpcEvent::Request { service, payload, reply, .. }) = &ev {
            if service == "greeter" {
                let mut ctx = Ctx::new(&mut node.swarm, net);
                let msg = format!("hello, {}!", String::from_utf8_lossy(payload));
                let _ = node.rpc.respond(&mut ctx, *reply, Status::Ok, msg.as_bytes());
                return None;
            }
        }
        Some(ev)
    }
}

fn main() -> anyhow::Result<()> {
    // 1. A two-host world: one LAN region.
    let mut topo = TopologyBuilder::new(1);
    let h1 = topo.public_host(0, LinkProfile::DATACENTER);
    let h2 = topo.public_host(0, LinkProfile::DATACENTER);
    let mut world = World::new(topo.build(7));

    // 2. Two nodes; the server runs a Greeter app.
    let server = LatticaNode::spawn(&mut world, h1, NodeConfig::with_seed(1));
    let client = LatticaNode::spawn(&mut world, h2, NodeConfig::with_seed(2));
    server.borrow_mut().app = Some(Box::new(Greeter));

    // 3. Dial (multiaddr carries transport + expected peer id).
    let server_ma = server.borrow().listen_addr();
    println!("dialing {server_ma}");
    client.borrow_mut().dial(&mut world.net, &server_ma)?;
    let server_peer = server.borrow().peer_id();
    assert!(run_until(&mut world, 5 * SECOND, || client
        .borrow()
        .swarm
        .is_connected(&server_peer)));
    println!("connected to {server_peer} (Noise-authenticated)");

    // 4. Unary RPC.
    {
        let mut c = client.borrow_mut();
        let LatticaNode { swarm, rpc, .. } = &mut *c;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        rpc.call(&mut ctx, &server_peer, "greeter", "hello", b"lattica")?;
    }
    let mut response = None;
    run_until(&mut world, 5 * SECOND, || {
        for e in client.borrow_mut().drain_events() {
            if let NodeEvent::Rpc(RpcEvent::Response { payload, rtt, .. }) = e {
                response = Some((String::from_utf8_lossy(&payload).to_string(), rtt));
            }
        }
        response.is_some()
    });
    let (text, rtt) = response.expect("rpc response");
    println!("rpc response: {text:?} (rtt {})", lattica::util::timefmt::fmt_ns(rtt));

    // 5. Content: publish on the server, fetch by CID on the client.
    let asset = b"model weights would go here".repeat(1000);
    let root = server
        .borrow_mut()
        .publish_blob(&mut world.net, "demo-asset", 1, &asset, 8 * 1024);
    println!("published {} as {root}", lattica::util::timefmt::fmt_bytes(asset.len() as u64));
    client
        .borrow_mut()
        .fetch_blob(&mut world.net, root, vec![server_peer]);
    run_until(&mut world, 5 * SECOND, || client.borrow().blockstore.has(&root));
    client
        .borrow_mut()
        .fetch_manifest_chunks(&mut world.net, &root, vec![server_peer])?;
    assert!(run_until(&mut world, 10 * SECOND, || {
        let c = client.borrow();
        lattica::content::DagManifest::load(&c.blockstore, &root)
            .map(|m| m.is_complete(&c.blockstore))
            .unwrap_or(false)
    }));
    let (fetched, n_chunks) = {
        let c = client.borrow();
        let m = lattica::content::DagManifest::load(&c.blockstore, &root)?;
        (m.assemble(&c.blockstore)?, m.chunks.len())
    };
    assert_eq!(fetched, asset);
    println!("fetched + verified {n_chunks} chunks by CID — quickstart OK");
    Ok(())
}
