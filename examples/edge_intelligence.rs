//! Edge intelligence (§3): NATed roadside cameras collaboratively share a
//! model without a central server.
//!
//! Eight "cameras" behind assorted consumer NATs form a mesh through one
//! relay. One camera (the aggregator of the hour) publishes an updated
//! traffic model; the rest learn of it via gossip and swarm-fetch it,
//! re-providing chunks to each other so the aggregator's uplink is not the
//! bottleneck — robust even though no node is publicly reachable.
//!
//! Every camera also registers a `camera.latest_model` control service:
//! the pull path for a camera whose gossip subscription lapsed, answered
//! `Unavailable` until that replica holds the model, so a retrying stub
//! with multiple camera targets fails over to whoever has it.
//!
//! Run: cargo run --release --example edge_intelligence

use lattica::content::{Cid, DagManifest};
use lattica::multiaddr::Multiaddr;
use lattica::netsim::nat::NatType;
use lattica::netsim::topology::{LinkProfile, TopologyBuilder};
use lattica::netsim::{World, SECOND};
use lattica::node::{LatticaNode, NodeConfig, NodeEvent};
use lattica::protocols::gossip::GossipEvent;
use lattica::protocols::Ctx;
use lattica::rpc::{CallOptions, Outcome, RetryPolicy, Service, Status, Stub};
use lattica::scenarios::stub_call_blocking;
use lattica::util::timefmt;
use std::cell::RefCell;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let n_cameras = 6usize;
    let mut topo = TopologyBuilder::paper_regions();
    let h_relay = topo.public_host(0, LinkProfile::DATACENTER);
    let nat_kinds = [
        NatType::FullCone,
        NatType::RestrictedCone,
        NatType::PortRestrictedCone,
        NatType::Symmetric,
    ];
    let cam_hosts: Vec<u32> = (0..n_cameras)
        .map(|i| {
            let nat = topo.nat(1 + i % 2, nat_kinds[i % 4], LinkProfile::BROADBAND);
            topo.natted_host(nat, LinkProfile::UNLIMITED)
        })
        .collect();
    let mut world = World::new(topo.build(808));
    let relay = LatticaNode::spawn(&mut world, h_relay, NodeConfig::relay(1));
    let cams: Vec<_> = cam_hosts
        .iter()
        .enumerate()
        .map(|(i, &h)| LatticaNode::spawn(&mut world, h, NodeConfig::with_seed(10 + i as u64)))
        .collect();

    // All cameras connect + reserve on the relay, subscribe to the topic.
    let relay_ma = relay.borrow().listen_addr();
    let relay_peer = relay.borrow().peer_id();
    for c in &cams {
        c.borrow_mut().dial(&mut world.net, &relay_ma)?;
    }
    world.run_for(2 * SECOND);
    for c in &cams {
        c.borrow_mut().swarm.relay_reserve(&mut world.net, &relay_peer)?;
        let mut nd = c.borrow_mut();
        let LatticaNode { swarm, gossip, .. } = &mut *nd;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        gossip.subscribe(&mut ctx, "traffic-model");
    }
    world.run_for(SECOND);

    // Mesh: every camera opens a circuit to the next two (ring + chord),
    // giving gossip and bitswap multiple NAT-traversed paths. Retried
    // until the links verify.
    for attempt in 0..10 {
        let mut missing = 0;
        for i in 0..n_cameras {
            for d in [1usize, 2, 3] {
                let target = cams[(i + d) % n_cameras].borrow().peer_id();
                if !cams[i].borrow().swarm.is_connected(&target) {
                    missing += 1;
                    let circuit = Multiaddr::circuit(relay_ma.clone(), target);
                    let _ = cams[i].borrow_mut().dial(&mut world.net, &circuit);
                }
            }
        }
        if missing == 0 && attempt > 0 {
            break;
        }
        world.run_for(2 * SECOND);
    }
    let connected: usize = cams
        .iter()
        .enumerate()
        .map(|(i, c)| {
            cams.iter()
                .enumerate()
                .filter(|(j, o)| i != *j && c.borrow().swarm.is_connected(&o.borrow().peer_id()))
                .count()
        })
        .sum();
    println!("mesh: {n_cameras} NATed cameras, {connected} directed peer links via relay circuits");

    // Every camera serves the model pointer once it holds the model
    // (`Unavailable` before that, so stub retries fail over elsewhere).
    let model_cells: Vec<Rc<RefCell<Option<Cid>>>> = cams
        .iter()
        .map(|c| {
            let cell: Rc<RefCell<Option<Cid>>> = Rc::new(RefCell::new(None));
            let served = cell.clone();
            c.borrow_mut().register_service(Service::new("camera").unary(
                "latest_model",
                move |_node, _net, _ctx, _payload| match *served.borrow() {
                    Some(root) => Outcome::reply(root.as_bytes().to_vec()),
                    None => Outcome::fail(Status::Unavailable, "this replica has no model yet"),
                },
            ));
            cell
        })
        .collect();

    // Camera 0 publishes the new model and announces it.
    let model: Vec<u8> = {
        let mut rng = lattica::util::Rng::new(42);
        rng.gen_bytes(1024 * 1024)
    };
    let root = cams[0]
        .borrow_mut()
        .publish_blob(&mut world.net, "traffic-model", 1, &model, 128 * 1024);
    *model_cells[0].borrow_mut() = Some(root);
    {
        let mut nd = cams[0].borrow_mut();
        let LatticaNode { swarm, gossip, .. } = &mut *nd;
        let mut ctx = Ctx::new(swarm, &mut world.net);
        gossip.publish(&mut ctx, "traffic-model", root.as_bytes().to_vec());
    }
    println!("camera 0 published model v1: {} at {root}", timefmt::fmt_bytes(model.len() as u64));

    // Others: hear the announcement, fetch from anyone who has it.
    let t0 = world.net.now();
    let all_peers: Vec<_> = cams.iter().map(|c| c.borrow().peer_id()).collect();
    // Each camera reacts to the gossip announcement by driving sync_blob
    // (idempotent) until its copy is complete.
    let deadline = world.net.now() + 300 * SECOND;
    let mut announced = vec![false; n_cameras];
    announced[0] = true;
    loop {
        let mut all_done = true;
        for (i, c) in cams.iter().enumerate().skip(1) {
            if !announced[i] {
                let heard = c.borrow_mut().drain_events().into_iter().any(|e| {
                    matches!(e, NodeEvent::Gossip(GossipEvent::Received { .. }))
                });
                if heard {
                    announced[i] = true;
                }
            }
            if announced[i] {
                let providers: Vec<_> = all_peers
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != i)
                    .map(|(_, p)| *p)
                    .collect();
                if !c.borrow_mut().sync_blob(&mut world.net, root, &providers) {
                    all_done = false;
                }
            } else {
                all_done = false;
            }
        }
        if all_done || world.net.now() >= deadline {
            break;
        }
        world.run_for(SECOND / 5);
    }
    let ok = cams.iter().skip(1).all(|c| {
        let n = c.borrow();
        DagManifest::load(&n.blockstore, &root)
            .map(|m| m.is_complete(&n.blockstore))
            .unwrap_or(false)
    });
    assert!(ok, "model did not replicate to all cameras");
    for cell in model_cells.iter().skip(1) {
        *cell.borrow_mut() = Some(root);
    }
    let dt = (world.net.now() - t0) as f64 / 1e9;

    // Control-plane audit: camera 1 resolves the model pointer from its
    // neighbours (not the origin) through a failover stub — any replica
    // can answer now that the swarm replicated the model.
    let mut pointer_stub = Stub::new(
        "camera",
        vec![all_peers[2], all_peers[3 % n_cameras]],
    )
    .with_options(CallOptions {
        deadline: 10 * SECOND,
        retry: RetryPolicy::idempotent(),
        ..CallOptions::default()
    });
    let done = stub_call_blocking(
        &mut world,
        &cams[1],
        &mut pointer_stub,
        "latest_model",
        b"",
        10 * SECOND,
    )
    .expect("latest_model query");
    assert_eq!(done.status, Status::Ok, "{}", done.detail);
    assert_eq!(done.payload, root.as_bytes(), "pointer must match the published root");
    println!("camera 1 re-resolved the model pointer from a peer replica (camera.latest_model)");
    // Per-camera serving contribution (swarm effect).
    let served: Vec<u64> = cams
        .iter()
        .map(|c| c.borrow().bitswap.ledgers.values().map(|l| l.bytes_sent).sum())
        .collect();
    let origin_share = served[0] as f64 / served.iter().sum::<u64>().max(1) as f64;
    println!("replicated to all {} cameras in {dt:.2}s (virtual)", n_cameras - 1);
    println!(
        "origin served {:.0}% of bytes; peers served the rest (swarm offload)",
        origin_share * 100.0
    );
    for (i, s) in served.iter().enumerate() {
        println!("  cam {i}: served {}", timefmt::fmt_bytes(*s));
    }
    println!("edge_intelligence OK");
    Ok(())
}
